"""Synthetic-but-structured LM data pipeline.

No external corpora are available offline, so the pipeline generates a
deterministic, learnable token stream (a noisy Markov chain over the
vocabulary + copy motifs) — enough signal for the end-to-end training
example to show decreasing loss, and fully reproducible from a seed.

The pipeline produces already-sharded global batches: an iterator of
pytrees matching the model's batch contract (tokens / frames /
patch_embeds), sized (global_batch, seq+1).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 97            # Markov states
    copy_period: int = 24         # repeat motif every N tokens


class SyntheticLM:
    """Markov-chain + copy-motif synthetic corpus."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        rng = np.random.RandomState(data.seed)
        v = cfg.vocab_size
        s = data.n_states
        # sparse-ish row-stochastic transition over states
        trans = rng.dirichlet(np.full(8, 0.5), size=s)
        self._next_states = np.stack(
            [rng.choice(s, size=8, replace=False) for _ in range(s)])
        self._trans = trans
        self._state_tokens = rng.randint(0, v, size=s)

    def _sample_stream(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        s = rng.randint(self.data.n_states)
        out = np.empty(n, np.int32)
        for i in range(n):
            if self.data.copy_period and i % self.data.copy_period == 0 and i >= self.data.copy_period:
                out[i] = out[i - self.data.copy_period]  # copy motif
                continue
            nxt = rng.choice(8, p=self._trans[s])
            s = self._next_states[s, nxt]
            out[i] = self._state_tokens[s]
        return out

    def batches(self, n_batches: int | None = None) -> Iterator[dict]:
        cfg, d = self.cfg, self.data
        i = 0
        while n_batches is None or i < n_batches:
            rng = np.random.RandomState(d.seed + 1000 + i)
            toks = np.stack([self._sample_stream(rng, d.seq_len + 1)
                             for _ in range(d.global_batch)])
            batch = {"tokens": toks}
            if cfg.frontend == "vision":
                p = cfg.frontend_len or 16
                batch["patch_embeds"] = rng.randn(
                    d.global_batch, p, cfg.frontend_dim).astype(np.float32)
            if cfg.family == "encdec":
                from repro.models.model import encdec_enc_len
                e = encdec_enc_len(d.seq_len)
                batch["frames"] = rng.randn(
                    d.global_batch, e, cfg.frontend_dim).astype(np.float32)
            yield batch
            i += 1


def microbatch_split(batch: dict, n_micro: int) -> dict:
    """Reshape (B, ...) -> (n_micro, B/n_micro, ...) for scan-accumulated
    gradient steps (train_step microbatching, DESIGN.md)."""
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}
