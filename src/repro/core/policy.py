"""SLO-aware precision controller (paper §3.2, Fig. 1b).

Decides, per serving iteration, whether to run the next step in FP16
(quality) or FP8 (speed). NestedFP makes the switch free: both modes read
the same weight buffers, so the decision can follow load at iteration
granularity — far below the minutes-scale granularity of autoscaling.

The controller is deliberately simple and auditable (the paper's is too):
it estimates the next iteration's TPOT from a calibrated per-token cost
model and the current batch, and falls back to FP8 whenever the estimate
(or the recent measured p90) threatens the SLO. Hysteresis avoids
oscillation on the boundary.

Besides latency, KV **memory pressure** is a first-class FP8 trigger
(MorphServe's runtime signal, arXiv 2506.02006): when the paged engine's
free-block headroom drops below `free_block_frac_min`, imminent
preemptions threaten TPOT far more than the compute itself, so the
controller drops to FP8 early — the same hysteresis dwell governs the
return to FP16 once headroom recovers. Since every serving family pages
through one BlockManager (GQA K/V, MLA latent planes, hybrid
shared-attention blocks — serving/kvcache.py cache descriptors), the
signal covers deepseek/zamba-class memory pressure, not just GQA.

For sliding-window archs (gemma3's local:global layer groups),
`free_block_frac` reflects WINDOW-RECLAIMED headroom: local-layer
blocks that slide out of every future query's window are freed back to
the pool mid-generation (kvcache.py `slide_window`), so the trigger
fires on real exhaustion rather than the phantom pressure a
keep-everything layout would report for dead local-layer KV.
"""

from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass
class SLOConfig:
    ttft_ms: float = 200.0           # industry-standard interactive SLOs
    tpot_ms: float = 33.3
    headroom: float = 0.9            # act before the SLO is breached
    hysteresis_steps: int = 5        # min FP8 dwell before returning to FP16
    p90_window: int = 64             # measured-latency window
    free_block_frac_min: float = 0.1 # KV headroom below this forces FP8


@dataclasses.dataclass
class StepObservation:
    batch_tokens: int                # decode tokens in this iteration's batch
    queue_depth: int                 # requests waiting
    measured_step_ms: float | None   # wall time of the last step
    prefill_tokens: int = 0          # prompt-chunk tokens scheduled alongside
                                     # decode (chunked prefill shares the step)
    free_block_frac: float | None = None
                                     # allocatable fraction of the paged KV
                                     # pool — GQA K/V, MLA latent, or hybrid
                                     # shared-attn blocks alike (None: caller
                                     # has no pool, e.g. the simulator)


class DualPrecisionController:
    """Iteration-level FP16/FP8 selector."""

    def __init__(self, slo: SLOConfig, *,
                 fp16_ms_per_token: float, fp8_ms_per_token: float,
                 fixed_overhead_ms: float = 2.0):
        self.slo = slo
        self.fp16_ms_per_token = fp16_ms_per_token
        self.fp8_ms_per_token = fp8_ms_per_token
        self.fixed_overhead_ms = fixed_overhead_ms
        self._recent = collections.deque(maxlen=slo.p90_window)
        self._fp8_dwell = 0
        self.mode: str = "fp16"
        self.history: list[str] = []

    # -- cost model -----------------------------------------------------------
    def predict_step_ms(self, batch_tokens: int, mode: str) -> float:
        per_tok = self.fp16_ms_per_token if mode == "fp16" else self.fp8_ms_per_token
        return self.fixed_overhead_ms + per_tok * batch_tokens

    def _p90(self) -> float | None:
        if len(self._recent) < 8:
            return None
        s = sorted(self._recent)
        return s[int(0.9 * (len(s) - 1))]

    # -- decision -------------------------------------------------------------
    def decide(self, obs: StepObservation) -> str:
        if obs.measured_step_ms is not None:
            self._recent.append(obs.measured_step_ms)

        budget = self.slo.tpot_ms * self.slo.headroom
        # chunked prefill rides the same iteration as decode, so its token
        # budget stretches the step just like decode tokens do
        pred_fp16 = self.predict_step_ms(
            obs.batch_tokens + obs.prefill_tokens, "fp16")
        p90 = self._p90()
        # free-block headroom is a leading indicator: exhaustion means
        # preemption-and-recompute, which costs far more than the step
        mem_pressure = (obs.free_block_frac is not None
                        and obs.free_block_frac < self.slo.free_block_frac_min)
        overloaded = (pred_fp16 > budget
                      or (p90 is not None and p90 > budget)
                      or mem_pressure)

        if overloaded:
            self.mode = "fp8"
            self._fp8_dwell = self.slo.hysteresis_steps
        elif self.mode == "fp8":
            self._fp8_dwell -= 1
            if self._fp8_dwell <= 0:
                self.mode = "fp16"
        self.history.append(self.mode)
        return self.mode

    # -- reporting ------------------------------------------------------------
    def fp16_time_fraction(self) -> float:
        if not self.history:
            return 1.0
        return self.history.count("fp16") / len(self.history)
