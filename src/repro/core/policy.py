"""SLO-aware precision controller (paper §3.2, Fig. 1b).

Decides, per serving iteration, whether to run the next step in FP16
(quality) or FP8 (speed). NestedFP makes the switch free: both modes read
the same weight buffers, so the decision can follow load at iteration
granularity — far below the minutes-scale granularity of autoscaling.

The controller is deliberately simple and auditable (the paper's is too):
it estimates the next iteration's TPOT from a calibrated per-token cost
model and the current batch, and falls back to FP8 whenever the estimate
(or the recent measured p90) threatens the SLO. Hysteresis avoids
oscillation on the boundary.

Besides latency, KV **memory pressure** is a first-class FP8 trigger
(MorphServe's runtime signal, arXiv 2506.02006): when the paged engine's
free-block headroom drops below `free_block_frac_min`, imminent
preemptions threaten TPOT far more than the compute itself, so the
controller drops to FP8 early — the same hysteresis dwell governs the
return to FP16 once headroom recovers. Since every serving family pages
through one BlockManager (GQA K/V, MLA latent planes, hybrid
shared-attention blocks — serving/kvcache.py cache descriptors), the
signal covers deepseek/zamba-class memory pressure, not just GQA.

For sliding-window archs (gemma3's local:global layer groups),
`free_block_frac` reflects WINDOW-RECLAIMED headroom: local-layer
blocks that slide out of every future query's window are freed back to
the pool mid-generation (kvcache.py `slide_window`), so the trigger
fires on real exhaustion rather than the phantom pressure a
keep-everything layout would report for dead local-layer KV.
"""

from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass
class SLOConfig:
    ttft_ms: float = 200.0           # industry-standard interactive SLOs
    tpot_ms: float = 33.3
    headroom: float = 0.9            # act before the SLO is breached
    hysteresis_steps: int = 5        # min FP8 dwell before returning to FP16
    p90_window: int = 64             # measured-latency window
    free_block_frac_min: float = 0.1 # KV headroom below this forces FP8


@dataclasses.dataclass
class StepObservation:
    batch_tokens: int                # decode tokens in this iteration's batch
    queue_depth: int                 # requests waiting
    measured_step_ms: float | None   # wall time of the last step
    prefill_tokens: int = 0          # prompt-chunk tokens scheduled alongside
                                     # decode (chunked prefill shares the step)
    free_block_frac: float | None = None
                                     # allocatable fraction of the paged KV
                                     # pool — GQA K/V, MLA latent, or hybrid
                                     # shared-attn blocks alike (None: caller
                                     # has no pool, e.g. the simulator)
    spec_drafted: int = 0            # draft tokens verified in the last step
    spec_accepted: int = 0           # ... of which the model confirmed


class DualPrecisionController:
    """Iteration-level FP16/FP8 selector."""

    def __init__(self, slo: SLOConfig, *,
                 fp16_ms_per_token: float, fp8_ms_per_token: float,
                 fixed_overhead_ms: float = 2.0):
        self.slo = slo
        self.fp16_ms_per_token = fp16_ms_per_token
        self.fp8_ms_per_token = fp8_ms_per_token
        self.fixed_overhead_ms = fixed_overhead_ms
        # measured step times PER MODE. One shared deque mixed FP8 and
        # FP16 samples: after an FP8 dwell the fast-mode samples dragged
        # the p90 under budget, the controller returned to FP16, the
        # first slow FP16 step re-triggered FP8, and the cycle flapped —
        # every measured decision must be made against samples of the
        # mode it is predicting (FP16).
        self._recent = {m: collections.deque(maxlen=slo.p90_window)
                        for m in ("fp16", "fp8")}
        self._fp8_dwell = 0
        self.mode: str = "fp16"
        self.history: list[str] = []

    # -- cost model -----------------------------------------------------------
    def predict_step_ms(self, batch_tokens: int, mode: str) -> float:
        per_tok = self.fp16_ms_per_token if mode == "fp16" else self.fp8_ms_per_token
        return self.fixed_overhead_ms + per_tok * batch_tokens

    def _p90(self, mode: str = "fp16") -> float | None:
        recent = self._recent[mode]
        if len(recent) < 8:
            return None
        s = sorted(recent)
        return s[int(0.9 * (len(s) - 1))]

    # -- decision -------------------------------------------------------------
    def decide(self, obs: StepObservation) -> str:
        if obs.measured_step_ms is not None:
            # the sample measures the PREVIOUS step, which ran in the
            # previously-decided mode — tag it accordingly
            prev = self.history[-1] if self.history else self.mode
            self._recent[prev].append(obs.measured_step_ms)

        budget = self.slo.tpot_ms * self.slo.headroom
        # chunked prefill rides the same iteration as decode, so its token
        # budget stretches the step just like decode tokens do
        pred_fp16 = self.predict_step_ms(
            obs.batch_tokens + obs.prefill_tokens, "fp16")
        pred_over = pred_fp16 > budget
        # the measured fallback asks "would FP16 violate the SLO?", so it
        # must read FP16 samples only — FP8 dwell samples say nothing
        # about FP16 latency
        p90 = self._p90("fp16")
        measured_over = p90 is not None and p90 > budget
        # free-block headroom is a leading indicator: exhaustion means
        # preemption-and-recompute, which costs far more than the step
        mem_pressure = (obs.free_block_frac is not None
                        and obs.free_block_frac < self.slo.free_block_frac_min)
        overloaded = pred_over or measured_over or mem_pressure

        if overloaded:
            self.mode = "fp8"
            self._fp8_dwell = self.slo.hysteresis_steps
            if measured_over and not (pred_over or mem_pressure) \
                    and self.history and self.history[-1] == "fp8":
                # evidence-only overload while already dwelling in FP8:
                # the FP16 deque cannot refresh (FP8 steps add no FP16
                # samples), so age the stale evidence one sample per
                # step — once it drains, the controller re-probes FP16
                # instead of trusting pre-overload measurements forever.
                self._recent["fp16"].popleft()
        elif self.mode == "fp8":
            self._fp8_dwell -= 1
            if self._fp8_dwell <= 0:
                self.mode = "fp16"
        self.history.append(self.mode)
        return self.mode

    # -- reporting ------------------------------------------------------------
    def fp16_time_fraction(self) -> float:
        if not self.history:
            return 1.0
        return self.history.count("fp16") / len(self.history)


# =============================================================================
# speculation-length policy (serving/speculate.py drafting)
# =============================================================================

@dataclasses.dataclass
class RestorePolicy:
    """SLO guard for the tiered-KV restore path (serving/engine.py).

    Restoring a host-tier prefix block is an h2d scatter that shares the
    step with live decodes, so an unbounded restore queue would blow
    TPOT for every active sequence. Two knobs bound it:

    * `max_restore_bytes_per_step` caps the bytes each step's
      `_drain_restores` uploads (the engine always grants at least one
      block so gated rows make progress — the cap shapes latency, it
      cannot deadlock a sequence).
    * `max_queue_bytes` is the admission gate: once the queued restore
      backlog reaches it, new prefix matches fall back to plain
      recompute (`admit() -> False`, counted in
      `stats["restore_fallbacks"]`) instead of piling on. Zero disables
      host-tier matching outright (spills still happen — the tier keeps
      filling for persistence — but nothing is ever restored).

    `from_slo` derives the per-step cap from a TPOT budget: spend at
    most `frac` of each step's latency budget on restore h2d traffic at
    the given link bandwidth."""
    max_restore_bytes_per_step: int = 32 << 20
    max_queue_bytes: int = 256 << 20

    def admit(self, queued_bytes: int) -> bool:
        """May a new admission match host-tier blocks (enqueueing more
        restores), given the current restore backlog?"""
        return queued_bytes < self.max_queue_bytes

    def grant(self, queued_bytes: int) -> int:
        """Restore-byte budget for this step."""
        return self.max_restore_bytes_per_step

    @classmethod
    def from_slo(cls, slo: SLOConfig, *, h2d_gbps: float = 16.0,
                 frac: float = 0.25, queue_steps: int = 8) -> RestorePolicy:
        """Tie the caps to the TPOT SLO: `frac` of each step's latency
        budget goes to restore uploads at `h2d_gbps` link bandwidth, and
        the admission gate tolerates a backlog worth `queue_steps`
        steps of that budget."""
        per_step = int(slo.tpot_ms * slo.headroom * frac / 1e3
                       * h2d_gbps * 1e9)
        return cls(max_restore_bytes_per_step=max(per_step, 1),
                   max_queue_bytes=max(per_step * queue_steps, 1))

    def scaled(self, scale: float) -> RestorePolicy:
        """A tightened (or relaxed) copy — the DegradePolicy swaps this
        in on survivors while the fleet runs short-handed: restore h2d
        traffic competes with the extra decode load, so both the
        per-step grant and the admission backlog shrink together."""
        return RestorePolicy(
            max_restore_bytes_per_step=max(
                1, int(self.max_restore_bytes_per_step * scale)),
            max_queue_bytes=max(1, int(self.max_queue_bytes * scale)))


# =============================================================================
# fleet-level graceful degradation (serving/router.py)
# =============================================================================

@dataclasses.dataclass
class DegradeDecision:
    """What the router applies to SURVIVOR replicas this step."""
    active: bool                     # running short-handed (or dwelling)
    force_fp8: bool                  # pin survivors to FP8
    shed_budget_tokens: int | None   # per-replica outstanding-token cap
                                     # for NEW admissions (None: admit all)
    restore_scale: float             # RestorePolicy tightening factor


class DegradePolicy:
    """Fleet-capacity analogue of the `DualPrecisionController`: when
    live replicas drop below the fleet size, survivors absorb the dead
    replica's load — NestedFP makes FP8 the free knob for that (same
    weights, iteration-granular switch), admission shedding bounds the
    backlog a survivor may accumulate, and tightened restore grants keep
    host-tier h2d traffic from competing with the extra decode work.

    Recovery uses the same hysteresis discipline the dual-precision
    controller applies to FP16 re-probes: after capacity returns, the
    degraded regime DWELLS for `hysteresis_steps` more steps before
    FP16 (and full grants/admissions) are probed again — a flapping
    replica must not flap the fleet's precision with it."""

    def __init__(self, *, force_fp8: bool = True,
                 shed_budget_tokens: int | None = None,
                 restore_scale: float = 0.5,
                 hysteresis_steps: int = 8):
        self.force_fp8 = force_fp8
        self.shed_budget_tokens = shed_budget_tokens
        self.restore_scale = restore_scale
        self.hysteresis_steps = hysteresis_steps
        self.active = False
        self._dwell = 0
        self.history: list[bool] = []

    def decide(self, live: int, total: int) -> DegradeDecision:
        if live < total:
            self.active = True
            self._dwell = self.hysteresis_steps
        elif self.active:
            self._dwell -= 1
            if self._dwell <= 0:
                self.active = False
        self.history.append(self.active)
        return DegradeDecision(
            active=self.active,
            force_fp8=self.force_fp8 and self.active,
            shed_budget_tokens=self.shed_budget_tokens if self.active
            else None,
            restore_scale=self.restore_scale if self.active else 1.0)

    def degraded_step_fraction(self) -> float:
        if not self.history:
            return 0.0
        return sum(self.history) / len(self.history)


# =============================================================================

@dataclasses.dataclass
class SpeculationConfig:
    """Knobs for n-gram speculative decoding (serving/speculate.py) and
    the adaptive draft-length policy below.

    K is the per-row draft budget: every decode step verifies up to K
    drafted tokens in one C=K+1 ragged `paged_step` chunk, so K trades
    verification compute (wasted on rejected tails) against accepted
    tokens per dispatch. DISCO-style adaptation tracks the recent
    acceptance rate and walks K inside [k_min, k_max]."""
    k_max: int = 8                   # draft-length ceiling
    k_min: int = 1                   # floor > 0 keeps the signal alive —
                                     # K=0 would draft nothing and the
                                     # acceptance stream would go silent
    k_init: int = 4
    ngram_max: int = 3               # longest suffix n-gram matched first
    ngram_min: int = 1
    adapt_window: int = 16           # recent steps in the acceptance window
    adapt_min_drafted: int = 8       # don't adapt on fewer drafted tokens
    accept_hi: float = 0.7           # grow K above this acceptance rate
    accept_lo: float = 0.3           # shrink K below it


class AdaptiveKController:
    """Per-step draft-length selector, driven by the SAME
    `StepObservation` stream the dual-precision controller reads: the
    engine reports how many draft tokens the last step verified and how
    many the model confirmed, and K walks toward the regime where
    verification work is actually paying out (DISCO, arXiv 2406.*;
    llmserve FUTURE item 4)."""

    def __init__(self, cfg: SpeculationConfig):
        assert 0 < cfg.k_min <= cfg.k_init <= cfg.k_max
        self.cfg = cfg
        self.k = cfg.k_init
        self._recent = collections.deque(maxlen=cfg.adapt_window)
        self.history: list[int] = []

    def acceptance_rate(self) -> float:
        drafted = sum(d for d, _ in self._recent)
        return sum(a for _, a in self._recent) / drafted if drafted else 0.0

    def decide(self, obs: StepObservation) -> int:
        if obs.spec_drafted:
            self._recent.append((obs.spec_drafted, obs.spec_accepted))
        drafted = sum(d for d, _ in self._recent)
        if drafted >= self.cfg.adapt_min_drafted:
            rate = self.acceptance_rate()
            if rate >= self.cfg.accept_hi:
                self.k = min(self.k + 1, self.cfg.k_max)
            elif rate <= self.cfg.accept_lo:
                self.k = max(self.k - 1, self.cfg.k_min)
        self.history.append(self.k)
        return self.k
