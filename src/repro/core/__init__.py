from repro.core.nestedfp import (
    NestedTensor, encode, decode, fp8_view, fp8_dequant,
    is_applicable, is_applicable_values, split_stats,
    FP8_DEQUANT_SCALE, NESTED_SCALE_LOG2, E4M3_MAX,
)
from repro.core.linear import NestedLinearParams, nested_linear, nest_weight_tree
from repro.core.policy import DualPrecisionController, SLOConfig, StepObservation
