"""Version guards for the JAX APIs this codebase uses across 0.4.x → 0.6.x.

Installed JAX may be as old as 0.4.37, which lacks
`jax.sharding.get_abstract_mesh`, `jax.sharding.AxisType`, and the
`axis_types=` kwarg of `jax.make_mesh`. Callers go through these shims so
the new-API path is taken when available and the legacy path (thread-local
physical mesh, plain `Mesh` construction) otherwise.
"""

from __future__ import annotations

import jax
import numpy as np


def get_ambient_mesh():
    """The mesh visible at trace time: the abstract mesh on new JAX, the
    thread-local physical mesh (set by `with mesh:`) on 0.4.x. Either way
    the result exposes `.axis_names` and a dict-like `.shape`; with no
    ambient mesh, `axis_names` is empty."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    from jax.interpreters import pxla
    return pxla.thread_resources.env.physical_mesh


def mesh_context(mesh):
    """Context manager installing `mesh` as the ambient mesh for traces
    opened inside it: `jax.sharding.use_mesh` where it exists (sets the
    abstract mesh new `get_abstract_mesh` reports), the mesh's own
    thread-local context on 0.4.x (what `get_ambient_mesh` falls back
    to). Lets `shard_hint` constraints fire inside serving dispatches
    without callers caring which API generation is installed."""
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def make_compat_mesh(shape, axis_names, *, devices=None):
    """`jax.make_mesh` with explicit-Auto axis types where supported.

    0.4.x `jax.make_mesh` has no `axis_types` kwarg (all axes are Auto
    implicitly, which is exactly what we want); some very old versions
    lack `jax.make_mesh` entirely, where a reshaped `Mesh` is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    make = getattr(jax, "make_mesh", None)
    if make is not None:
        if axis_type is not None:
            return make(shape, axis_names, devices=devices,
                        axis_types=(axis_type.Auto,) * len(axis_names))
        return make(shape, axis_names, devices=devices)
    devs = np.asarray(devices if devices is not None
                      else jax.devices()[: int(np.prod(shape))])
    return jax.sharding.Mesh(devs.reshape(shape), axis_names)
