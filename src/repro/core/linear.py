"""NestedLinear: a linear layer readable at two precisions (paper §4).

One weight copy (2 bytes/weight) serves both modes:
  mode="fp16": lossless path — plain f16 GEMM semantics via the
               reconstructing kernel (or its ref oracle).
  mode="fp8":  fast path — dynamic absmax activation quant, GEMM on the
               upper byte, dequant by act_scale * 2^-8. `act_quant`
               picks the scale granularity: "per_tensor" (the paper's
               scheme, default) or "per_token" — one scale per
               activation row, which makes every token's result
               independent of what else shares the dispatch. The
               serving engine runs per_token so fp8 generation is
               BATCH-INVARIANT: continuous batching and speculative
               C=K+1 verification chunks reshape the batch every step,
               and a per-tensor amax would let co-batched tokens
               perturb each other's rounding (outputs then differ
               run-to-run for the same request).
Exception tensors (any |w| > 1.75) always run the f16 path, in both modes
(paper §4.2 "Handling Exception Layers").

The mode is a *traced-time static* argument: the serving engine compiles
one executable per precision and flips between them per iteration at zero
weight-copy cost (both executables alias the same buffers).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import nestedfp as nf
from repro.core import quant
from repro.kernels import ops

Mode = Literal["fp16", "fp8"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NestedLinearParams:
    """Weight (K,N) in NestedFP form + optional bias (N,)."""
    weight: nf.NestedTensor
    bias: jax.Array | None

    def tree_flatten(self):
        return (self.weight, self.bias), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, key, in_features: int, out_features: int,
               use_bias: bool = False, scale: float | None = None,
               dtype=jnp.float16) -> "NestedLinearParams":
        scale = scale if scale is not None else in_features ** -0.5
        w = (jax.random.normal(key, (in_features, out_features), jnp.float32)
             * scale).astype(dtype)
        b = jnp.zeros((out_features,), jnp.float32) if use_bias else None
        return cls(weight=nf.NestedTensor.from_f16(w), bias=b)

    @classmethod
    def from_weights(cls, w: jax.Array, bias: jax.Array | None = None
                     ) -> "NestedLinearParams":
        return cls(weight=nf.NestedTensor.from_f16(w), bias=bias)

    @property
    def shape(self):
        return self.weight.shape


def nested_linear(params: NestedLinearParams, x: jax.Array, *,
                  mode: Mode = "fp16", backend: str | None = None,
                  out_dtype=None, fast_accum: bool = False,
                  act_quant: str = "per_tensor") -> jax.Array:
    """Apply y = x @ W (+ b) at the selected precision.

    x: (..., K). Returns (..., N) in out_dtype (default: x.dtype).
    fast_accum: bf16 dot outputs => cross-shard partial sums travel in
    bf16 (halves tensor-parallel all-reduce bytes; serving-only trade).
    act_quant: fp8 activation scale granularity — "per_tensor" (paper
    scheme) or "per_token" (batch-invariant; module docstring).
    """
    out_dtype = out_dtype or x.dtype
    acc = jnp.bfloat16 if fast_accum else jnp.float32
    w = params.weight
    if w.is_exception or mode == "fp16":
        if w.is_exception:
            y = ops.matmul_f16(x.astype(jnp.float16), w.read_f16(),
                               backend=backend, out_dtype=acc, acc_dtype=acc)
        else:
            y = ops.matmul_nested_f16(x.astype(jnp.float16), w.upper, w.lower,
                                      backend=backend, out_dtype=acc,
                                      acc_dtype=acc)
    elif mode == "fp8":
        if act_quant == "per_token":
            xq, scale = quant.quantize_act_per_token(x)
            # (..., 1) row scales, flattened to match the GEMM's (M, K)
            # view of x — each row's dequant is independent of the batch
            scale = scale.reshape(-1, 1)
        else:
            xq, scale = quant.quantize_act_per_tensor(x)
        y = ops.matmul_nested_fp8(xq, w.upper, scale, backend=backend,
                                  out_dtype=acc, acc_dtype=acc)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if params.bias is not None:
        y = y + params.bias
    return y.astype(out_dtype)


def nest_weight_tree(params, path_filter=None):
    """Convert every 2-D f16/f32 weight leaf of a pytree into NestedTensor.

    Used by the serving engine to convert a trained checkpoint into
    serving form. `path_filter(path) -> bool` limits conversion (e.g.
    exclude embeddings, as the paper quantizes only linear layers).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        is_mat = hasattr(leaf, "ndim") and leaf.ndim >= 2
        keep = path_filter(jax.tree_util.keystr(path)) if path_filter else True
        if is_mat and keep:
            out.append(nf.NestedTensor.from_f16(jnp.asarray(leaf, jnp.float16)))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
