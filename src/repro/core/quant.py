"""FP8 quantization utilities: activation quant + the paper's FP8 baseline.

The paper's comparison baseline (Table 2, "FP8(B)") is E4M3 with
per-channel absmax weight scales and per-token absmax activation scales.
NestedFP8 ("FP8(N)") instead uses ONE global weight scale (2^8) and
per-tensor absmax activation scales. Both are implemented here so the
accuracy benchmark can reproduce the Table 2 comparison.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.nestedfp import E4M3_MAX

_EPS = 1e-12


def _to_e4m3(x: jax.Array) -> jax.Array:
    return jnp.clip(x, -E4M3_MAX, E4M3_MAX).astype(jnp.float8_e4m3fn)


# -- activations -------------------------------------------------------------

def quantize_act_per_tensor(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dynamic per-tensor absmax E4M3 quant (NestedFP's activation scheme)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), _EPS)
    scale = amax / E4M3_MAX                      # dequant scale
    q = _to_e4m3(x.astype(jnp.float32) / scale)
    return q, scale.astype(jnp.float32)


def quantize_act_per_token(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dynamic per-token absmax E4M3 quant (baseline FP8's scheme).

    x: (..., tokens, features); scale per token (broadcast over features).
    """
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                               keepdims=True), _EPS)
    scale = amax / E4M3_MAX
    q = _to_e4m3(x.astype(jnp.float32) / scale)
    return q, scale.astype(jnp.float32)


# -- weights (baseline only; NestedFP weights come from nestedfp.encode) ------

def quantize_weight_per_channel(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Static per-output-channel absmax E4M3 quant. w: (in, out)."""
    amax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0,
                               keepdims=True), _EPS)
    scale = amax / E4M3_MAX                      # (1, out)
    q = _to_e4m3(w.astype(jnp.float32) / scale)
    return q, scale.astype(jnp.float32)


def quantize_weight_per_tensor(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32))), _EPS)
    scale = amax / E4M3_MAX
    q = _to_e4m3(w.astype(jnp.float32) / scale)
    return q, scale.astype(jnp.float32)


# -- error metrics (accuracy benchmark, Table 2 proxy) ------------------------

def quant_error_metrics(w: jax.Array, w_hat: jax.Array) -> dict[str, float]:
    w = w.astype(jnp.float64) if jax.config.jax_enable_x64 else w.astype(jnp.float32)
    w_hat = w_hat.astype(w.dtype)
    err = w - w_hat
    mse = jnp.mean(err * err)
    sig = jnp.mean(w * w)
    cos = jnp.sum(w * w_hat) / jnp.maximum(
        jnp.linalg.norm(w.ravel()) * jnp.linalg.norm(w_hat.ravel()), _EPS)
    return {
        "mse": float(mse),
        "sqnr_db": float(10.0 * jnp.log10(jnp.maximum(sig, _EPS) / jnp.maximum(mse, _EPS))),
        "cosine": float(cos),
        "max_abs_err": float(jnp.max(jnp.abs(err))),
    }
