"""NestedFP dual-precision weight format (paper §4.2).

An FP16 (E5M10) value with |w| <= 1.75 has its exponent MSB equal to 0 and
splits losslessly into two bytes:

  upper = [S][E3 E2 E1 E0][M1 M2 M3']   -- a *valid* float8_e4m3fn encoding
                                            of w * 2^8 (RNE-rounded mantissa)
  lower = [M3 M4 M5 M6 M7 M8 M9 M10]    -- raw low mantissa bits

M3 is stored twice: rounded in `upper`, raw in `lower`. The pair acts as a
checksum recording whether RNE rounded up, which lets FP16 reconstruction
undo the rounding exactly (branch-free subtract, paper Fig. 6):

  corrected = (upper & 0x7F) - (lower >> 7)      # undo rounding carry
  bits      = (upper >> 7) << 15 | (corrected >> 1) << 8 | lower
  (only E/M1/M2 are taken from the corrected upper; M3..M10 all come raw
  from `lower`, so the duplicated M3 never needs correcting itself)

The 1.75 threshold is exactly the largest finite E4M3 magnitude (448)
divided by the fixed scale 2^8 (the FP16/E4M3 bias gap, 15 - 7 = 8).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# |w| <= 1.75  <=>  (bits & 0x7FFF) <= 0x3F00  (0x3F00 == f16 1.75)
F16_NESTED_ABS_MAX_BITS = 0x3F00
NESTED_SCALE_LOG2 = 8                # fixed global scale 2^8 (paper §4.2)
FP8_DEQUANT_SCALE = 2.0 ** -NESTED_SCALE_LOG2
E4M3_MAX = 448.0


def _as_u32(x: jax.Array) -> jax.Array:
    return x.astype(jnp.uint32)


def is_applicable_values(w: jax.Array) -> jax.Array:
    """Elementwise: can this f16 value be nested? (|w| <= 1.75, incl. +-0)"""
    bits = jax.lax.bitcast_convert_type(w.astype(jnp.float16), jnp.uint16)
    return (_as_u32(bits) & 0x7FFF) <= F16_NESTED_ABS_MAX_BITS


def is_applicable(w: jax.Array) -> jax.Array:
    """Tensor-level applicability (paper 'exception layer' predicate)."""
    return jnp.all(is_applicable_values(w))


def encode(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split f16 tensor into (upper, lower) uint8 tensors (offline, Fig 4a).

    Caller must ensure applicability; non-applicable tensors stay f16
    (exception layers). Values are processed bit-exactly:
      - magnitude = bits & 0x7FFF; keep = magnitude >> 7 (E4 bit is 0)
      - RNE on the dropped 7 mantissa bits, carry propagates into the
        exponent naturally via integer add (IEEE ordering property)
    """
    bits = _as_u32(jax.lax.bitcast_convert_type(w.astype(jnp.float16), jnp.uint16))
    sign = bits >> 15
    mag = bits & 0x7FFF
    keep = mag >> 7                       # [0 E3..E0 M1 M2 M3], 8 bits, bit7=0
    low = mag & 0x7F                      # dropped mantissa bits M4..M10
    round_up = (low > 0x40) | ((low == 0x40) & ((keep & 1) == 1))
    keep = keep + round_up.astype(jnp.uint32)
    upper = ((sign << 7) | (keep & 0x7F)).astype(jnp.uint8)
    lower = (mag & 0xFF).astype(jnp.uint8)
    return upper, lower


def decode(upper: jax.Array, lower: jax.Array) -> jax.Array:
    """Lossless FP16 reconstruction (online, Fig 4b / Fig 6), branch-free.

    If the checksum bits differ (M3' != M3) RNE rounded up; subtracting
    lower's MSB from the upper payload undoes the rounding including any
    carry that reached M2/M1/E.
    """
    u = _as_u32(upper)
    l = _as_u32(lower)
    sign = u >> 7
    corrected = (u & 0x7F) - (l >> 7)     # never underflows (see invariant)
    bits = (sign << 15) | ((corrected >> 1) << 8) | l
    return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.float16)


def fp8_view(upper: jax.Array) -> jax.Array:
    """Reinterpret the upper tensor as float8_e4m3fn == w * 2^8 (RNE)."""
    return jax.lax.bitcast_convert_type(upper, jnp.float8_e4m3fn)


def fp8_dequant(upper: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Materialize the FP8-mode weight values (w rounded to E4M3 grid)."""
    return fp8_view(upper).astype(dtype) * jnp.asarray(FP8_DEQUANT_SCALE, dtype)


# ---------------------------------------------------------------------------
# Tensor container: a weight tensor in NestedFP form (or f16 exception form)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NestedTensor:
    """A linear-layer weight stored once, readable at two precisions.

    Exactly one of the two layouts is live:
      applicable:    upper/lower uint8 tensors (together: the f16 bytes)
      exception:     raw f16 tensor (paper §4.2 'Handling Exception Layers')
    Both layouts occupy exactly 2 bytes/weight.
    """

    upper: jax.Array | None
    lower: jax.Array | None
    raw: jax.Array | None          # f16, only for exception tensors

    def tree_flatten(self):
        return (self.upper, self.lower, self.raw), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_f16(cls, w: jax.Array, force_exception: bool = False) -> "NestedTensor":
        """Offline pre-processing. Decides applicability on host."""
        w = jnp.asarray(w, jnp.float16)
        applicable = (not force_exception) and bool(is_applicable(w))
        if applicable:
            upper, lower = encode(w)
            return cls(upper=upper, lower=lower, raw=None)
        return cls(upper=None, lower=None, raw=w)

    # -- properties ----------------------------------------------------------
    @property
    def is_exception(self) -> bool:
        return self.raw is not None

    @property
    def shape(self):
        src = self.raw if self.raw is not None else self.upper
        return src.shape

    @property
    def nbytes_per_weight(self) -> int:
        return 2

    # -- reads ---------------------------------------------------------------
    def read_f16(self) -> jax.Array:
        """FP16-mode weights (bit-exact original)."""
        if self.is_exception:
            return self.raw
        return decode(self.upper, self.lower)

    def read_fp8(self) -> tuple[jax.Array, jax.Array]:
        """FP8-mode weights: (e4m3 tensor, scalar dequant scale).

        Exception tensors have no 8-bit form; they run in f16 even in FP8
        mode (paper: 'these layers are always executed in FP16').
        """
        if self.is_exception:
            raise ValueError("exception tensor has no FP8 form; use read_f16()")
        return fp8_view(self.upper), jnp.float32(FP8_DEQUANT_SCALE)


# ---------------------------------------------------------------------------
# Power-of-two per-channel scaling (beyond-paper, DESIGN.md §8).
#
# Arbitrary per-channel FP8 scales (the baseline quantizer's trick) would
# BREAK the paper's lossless-FP16 property: w/s rounds. But multiplying an
# f16 value by 2^k only shifts its exponent — bit-exact whenever the result
# stays normal/in-range — so per-channel exponents k_c give each output
# channel the full E4M3 resolution AND rescue channels with absmax > 1.75
# (Phi-4-style exception layers) while FP16 reads stay bit-lossless.
# Channels where the shift would be inexact (subnormal underflow) keep
# k_c = 0. Dequant scale in FP8 mode becomes the vector 2^-8 * 2^-k.
# ---------------------------------------------------------------------------

def pow2_channel_exponents(w: jax.Array) -> jax.Array:
    """Per-output-channel exponent k so absmax_c * 2^k <= 1.75, k in
    [-14, 14]. w: (..., N) with channels on the last axis."""
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)),
                     axis=tuple(range(w.ndim - 1)))
    k = jnp.floor(jnp.log2(1.75 / jnp.maximum(absmax, 1e-30)))
    return jnp.clip(k, -14, 14).astype(jnp.int32)


def encode_pow2(w: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (upper, lower, k) — channel-scaled nested encoding.

    Guarantees bit-exact FP16 roundtrip: channels whose shift is inexact
    (tiny subnormals shifted down) or still out of range fall back to
    k_c = 0; the caller checks tensor applicability on the scaled values."""
    w = jnp.asarray(w, jnp.float16)
    k = pow2_channel_exponents(w)
    scale = jnp.exp2(k.astype(jnp.float32)).astype(jnp.float16)
    ws = (w.astype(jnp.float32) * scale).astype(jnp.float16)
    back = (ws.astype(jnp.float32) / scale).astype(jnp.float16)
    exact = jnp.all(
        jax.lax.bitcast_convert_type(back, jnp.uint16)
        == jax.lax.bitcast_convert_type(w, jnp.uint16),
        axis=tuple(range(w.ndim - 1)))
    ok = exact & jnp.all(is_applicable_values(ws),
                         axis=tuple(range(w.ndim - 1)))
    k = jnp.where(ok, k, 0)
    scale = jnp.exp2(k.astype(jnp.float32)).astype(jnp.float16)
    ws = (w.astype(jnp.float32) * scale).astype(jnp.float16)
    upper, lower = encode(ws)
    return upper, lower, k


def decode_pow2(upper: jax.Array, lower: jax.Array, k: jax.Array) -> jax.Array:
    """Bit-exact inverse of encode_pow2 (for applicable channels)."""
    ws = decode(upper, lower)
    inv = jnp.exp2(-k.astype(jnp.float32))
    return (ws.astype(jnp.float32) * inv).astype(jnp.float16)


def fp8_dequant_scale_pow2(k: jax.Array) -> jax.Array:
    """Per-channel FP8 dequant vector: 2^-8 * 2^-k."""
    return (FP8_DEQUANT_SCALE * jnp.exp2(-k.astype(jnp.float32))
            ).astype(jnp.float32)


def is_applicable_pow2(w: jax.Array) -> jax.Array:
    """Tensor applicability under per-channel pow2 scaling (superset of
    the paper's fixed-scale applicability)."""
    w = jnp.asarray(w, jnp.float16)
    k = pow2_channel_exponents(w)
    scale = jnp.exp2(k.astype(jnp.float32)).astype(jnp.float16)
    ws = (w.astype(jnp.float32) * scale).astype(jnp.float16)
    back = (ws.astype(jnp.float32) / scale).astype(jnp.float16)
    exact = jnp.all(jax.lax.bitcast_convert_type(back, jnp.uint16)
                    == jax.lax.bitcast_convert_type(w, jnp.uint16))
    return exact & is_applicable(ws)


# ---------------------------------------------------------------------------
# Byte-planar f16 (beyond-paper "NestedKV", DESIGN.md §8): any f16 tensor
# splits into its high and low bytes. The HIGH byte [S EEEEE MM] is exactly
# a float8_e5m2 encoding of the round-toward-zero-truncated value — no
# applicability constraint, no scale. FP8-mode attention reads only the
# high plane (half the KV-cache HBM traffic); FP16 mode rejoins losslessly.
# ---------------------------------------------------------------------------

def split_bytes(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f16 -> (hi, lo) uint8 planes. hi is a valid float8_e5m2 tensor."""
    bits = _as_u32(jax.lax.bitcast_convert_type(x.astype(jnp.float16),
                                                jnp.uint16))
    return (bits >> 8).astype(jnp.uint8), (bits & 0xFF).astype(jnp.uint8)


def join_bytes(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Lossless inverse of split_bytes."""
    bits = (_as_u32(hi) << 8) | _as_u32(lo)
    return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.float16)


def e5m2_view(hi: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Read the high plane alone as float8_e5m2 (truncated-f16 values)."""
    return jax.lax.bitcast_convert_type(hi, jnp.float8_e5m2).astype(dtype)


def split_stats(w: jax.Array) -> dict[str, Any]:
    """Applicability diagnostics for a weight tensor (paper Table 3)."""
    w = jnp.asarray(w, jnp.float16)
    elem_ok = is_applicable_values(w)
    return {
        "numel": int(w.size),
        "applicable_fraction": float(jnp.mean(elem_ok.astype(jnp.float32))),
        "tensor_applicable": bool(jnp.all(elem_ok)),
        "abs_max": float(jnp.max(jnp.abs(w.astype(jnp.float32)))),
    }


# ---------------------------------------------------------------------------
# NumPy twin (offline/checkpoint tooling; no device involvement)
# ---------------------------------------------------------------------------

def encode_np(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    bits = w.astype(np.float16).view(np.uint16).astype(np.uint32)
    sign = bits >> 15
    mag = bits & 0x7FFF
    keep = mag >> 7
    low = mag & 0x7F
    round_up = (low > 0x40) | ((low == 0x40) & ((keep & 1) == 1))
    keep = keep + round_up.astype(np.uint32)
    upper = ((sign << 7) | (keep & 0x7F)).astype(np.uint8)
    lower = (mag & 0xFF).astype(np.uint8)
    return upper, lower


def decode_np(upper: np.ndarray, lower: np.ndarray) -> np.ndarray:
    u = upper.astype(np.uint32)
    l = lower.astype(np.uint32)
    sign = u >> 7
    corrected = (u & 0x7F) - (l >> 7)
    bits = ((sign << 15) | ((corrected >> 1) << 8) | l).astype(np.uint16)
    return bits.view(np.float16)
