"""AdamW + cosine schedule + global-norm clipping (pure JAX, pytree-based).

Supports a low-memory mode (bf16 first/second moments) used for the
largest assigned arch (deepseek-v3-671b) where f32 states exceed a v5e
pod's HBM (see EXPERIMENTS.md §Dry-run memory notes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    low_mem: bool = False          # bf16 moments


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params) -> dict:
    mdt = jnp.bfloat16 if cfg.low_mem else jnp.float32

    def zeros_like(p):
        return jnp.zeros(p.shape, mdt)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
    }


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0   # no decay on norms
        newp = p.astype(jnp.float32) - lr * (delta + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
