"""Top-level model: init / train loss / prefill / decode for all families.

Layer stacks are lax.scan'd over STACKED per-layer params (init via vmap)
— one compiled block body regardless of depth, which keeps the 80
dry-run compiles tractable (DESIGN.md §Distribution). Heterogeneity is
data-driven inside the scan:
  * gemma3 local/global pattern  -> scanned per-layer `window` array
    (window <= 0 means global attention)
  * zamba2 shared attention      -> lax.cond on (layer_idx % attn_every)
    with the shared block's params closed over; its 9 KV caches ride in
    the scan carry
  * deepseek-v3                  -> MLA attention + MoE mlp blocks

Phases: "train" (loss, remat'd blocks), "prefill" (emit KV caches),
"decode" (one token, fixed-capacity caches).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.layers import Runtime

CACHE_DTYPE = jnp.float16


# ---------------------------------------------------------------------------
# block init / apply (single layer; vmapped + scanned by the stacks)
# ---------------------------------------------------------------------------

def _attn_kind(cfg: ArchConfig) -> str:
    return "mla" if cfg.mla is not None else "gqa"


def _mlp_kind(cfg: ArchConfig) -> str:
    return "moe" if cfg.moe is not None else "dense"


def init_decoder_block(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p = {"ln1": L.init_rms_norm(cfg.d_model), "ln2": L.init_rms_norm(cfg.d_model)}
    if _attn_kind(cfg) == "mla":
        p["attn"] = MLA.init_mla(ks[0], cfg)
    else:
        p["attn"] = L.init_attention(ks[0], cfg)
    if _mlp_kind(cfg) == "moe":
        p["moe"] = MOE.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff)
    if cross:
        p["ln_cross"] = L.init_rms_norm(cfg.d_model)
        p["cross"] = L.init_cross_attention(ks[2], cfg)
    return p


def apply_decoder_block(rt: Runtime, p: dict, cfg: ArchConfig, x, *,
                        phase: str, positions, window=None, cache=None,
                        kv_len=None, memory=None, cross_cache=None,
                        causal: bool = True, paged=None):
    """Returns (x, new_cache, new_cross_cache, aux)."""
    aux = {}
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if _attn_kind(cfg) == "mla":
        a, new_cache = MLA.mla_attention(rt, p["attn"], cfg, h, phase=phase,
                                         positions=positions, cache=cache,
                                         kv_len=kv_len, paged=paged)
    else:
        a, new_cache = L.attention(rt, p["attn"], cfg, h, phase=phase,
                                   positions=positions, window=window,
                                   cache=cache, kv_len=kv_len, causal=causal,
                                   paged=paged)
    x = x + a
    new_cross = None
    if "cross" in p:
        hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        c, new_cross = L.cross_attention(rt, p["cross"], cfg, hc, memory,
                                         cache=cross_cache)
        x = x + c
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if _mlp_kind(cfg) == "moe":
        m, aux = MOE.moe_block(rt, p["moe"], cfg, h)
    else:
        m = L.swiglu(rt, p["mlp"], h)
    return x + m, new_cache, new_cross, aux


def init_ssm_block(key, cfg: ArchConfig) -> dict:
    return {"ln1": L.init_rms_norm(cfg.d_model),
            "mamba": M2.init_mamba2(key, cfg)}


def apply_ssm_block(rt: Runtime, p: dict, cfg: ArchConfig, x, *,
                    phase: str, cache=None, kv_len=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    y, new_cache = M2.mamba2_block(rt, p["mamba"], cfg, h, phase=phase,
                                   cache=cache, kv_len=kv_len)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _gqa_cache(cfg, n_layers, b, cap, planar: bool = False):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shp = (n_layers, b, cap, hkv, hd)
    if planar:   # byte-planar NestedKV (fp8 decode reads hi planes only)
        return {k: jnp.zeros(shp, jnp.uint8)
                for k in ("k_hi", "k_lo", "v_hi", "v_lo")}
    return {"k": jnp.zeros(shp, CACHE_DTYPE), "v": jnp.zeros(shp, CACHE_DTYPE)}


def _mla_cache(cfg, n_layers, b, cap):
    m = cfg.mla
    return {"c_kv": jnp.zeros((n_layers, b, cap, m.kv_lora_rank), CACHE_DTYPE),
            "k_rope": jnp.zeros((n_layers, b, cap, m.qk_rope_dim), CACHE_DTYPE)}


def _ssm_cache(cfg, n_layers, b):
    d_inner, n_heads, conv_ch = M2.ssm_dims(cfg)
    s = cfg.ssm
    gn2 = 2 * s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((n_layers, b, s.conv_width - 1, d_inner),
                            CACHE_DTYPE),
        "conv_bc": jnp.zeros((n_layers, b, s.conv_width - 1, gn2),
                             CACHE_DTYPE),
        "ssm": jnp.zeros((n_layers, b, n_heads, s.head_dim, s.d_state),
                         jnp.float32),
    }


def init_cache(cfg: ArchConfig, batch: int, capacity: int,
               planar: bool = False) -> dict:
    """Decode/prefill cache pytree for one model instance.

    planar=True stores GQA caches as byte planes (NestedKV)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.mla is not None:
            return {"attn": _mla_cache(cfg, cfg.n_layers, batch, capacity)}
        return {"attn": _gqa_cache(cfg, cfg.n_layers, batch, capacity, planar)}
    if fam == "ssm":
        return {"ssm": _ssm_cache(cfg, cfg.n_layers, batch)}
    if fam == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        sh = _gqa_cache(cfg, n_apps, batch, capacity)
        return {"ssm": _ssm_cache(cfg, cfg.n_layers, batch), "shared": sh}
    if fam == "encdec":
        enc_len = encdec_enc_len(capacity)
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cross = {"k": jnp.zeros((cfg.n_layers, batch, enc_len, hkv, hd), CACHE_DTYPE),
                 "v": jnp.zeros((cfg.n_layers, batch, enc_len, hkv, hd), CACHE_DTYPE)}
        return {"attn": _gqa_cache(cfg, cfg.n_layers, batch, capacity),
                "cross": cross}
    raise ValueError(fam)


def cache_descriptor(cfg: ArchConfig, planar: bool = False) -> "KV.CacheDescriptor":
    """Per-family serving cache descriptor (serving/kvcache.py): which
    planes are block-paged and which are slot-resident, with per-token /
    per-slot byte accounting. Raises for enc-dec (engine-unsupported)."""
    from repro.serving import kvcache as KV

    kind = cfg.cache_kind
    if kind == "encdec":
        raise NotImplementedError(
            "engine serves decoder-only archs; enc-dec serving is "
            "covered by the dry-run + benchmarks")
    cd = "float16"                                   # CACHE_DTYPE name
    if kind == "mla":
        if planar:
            raise ValueError("byte-planar NestedKV applies to GQA K/V "
                             "planes only, not MLA latents")
        m = cfg.mla
        return KV.CacheDescriptor("mla", planes=(
            KV.PlaneSpec("c_kv", cfg.n_layers, (m.kv_lora_rank,), cd),
            KV.PlaneSpec("k_rope", cfg.n_layers, (m.qk_rope_dim,), cd)))
    if kind == "gqa":
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        # gemma3-style local:global layer split — per-layer-group window
        # metadata so the BlockManager can slide-free local-layer blocks
        # while global-layer blocks stay pinned (kvcache.py LayerGroup)
        groups: tuple[KV.LayerGroup, ...] = ()
        if cfg.sliding_window and cfg.swa_pattern:
            p = cfg.swa_pattern
            glob = tuple(i for i in range(cfg.n_layers) if i % p == p - 1)
            loc = tuple(i for i in range(cfg.n_layers) if i % p != p - 1)
            groups = (KV.LayerGroup("global", None, glob),
                      KV.LayerGroup("local", int(cfg.sliding_window), loc))
        if planar:
            return KV.CacheDescriptor("gqa", planes=tuple(
                KV.PlaneSpec(n, cfg.n_layers, (hkv, hd), "uint8")
                for n in ("k_hi", "k_lo", "v_hi", "v_lo")), groups=groups)
        return KV.CacheDescriptor("gqa", planes=(
            KV.PlaneSpec("k", cfg.n_layers, (hkv, hd), cd),
            KV.PlaneSpec("v", cfg.n_layers, (hkv, hd), cd)), groups=groups)
    if planar:
        raise ValueError("byte-planar NestedKV applies to GQA K/V planes "
                         "only, not SSM/hybrid state")
    d_inner, n_heads, _ = M2.ssm_dims(cfg)
    s = cfg.ssm
    gn2 = 2 * s.n_groups * s.d_state
    slot_planes = (
        KV.SlotPlaneSpec("conv_x", (cfg.n_layers, s.conv_width - 1, d_inner),
                         cd),
        KV.SlotPlaneSpec("conv_bc", (cfg.n_layers, s.conv_width - 1, gn2),
                         cd),
        KV.SlotPlaneSpec("ssm", (cfg.n_layers, n_heads, s.head_dim,
                                 s.d_state), "float32"),
    )
    if kind == "ssm":
        return KV.CacheDescriptor("ssm", slot_planes=slot_planes,
                                  prefix_cacheable=False)
    if not cfg.attn_every or cfg.n_layers % cfg.attn_every:
        # paged hybrid execution is grouped (one shared-attn application
        # per attn_every layers); fail at descriptor construction rather
        # than mid-trace on the first engine step
        raise ValueError(
            f"hybrid paged serving requires attn_every | n_layers, got "
            f"{cfg.n_layers} % {cfg.attn_every}")
    n_apps = cfg.n_layers // cfg.attn_every          # hybrid
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return KV.CacheDescriptor(
        "hybrid",
        planes=(KV.PlaneSpec("k", n_apps, (hkv, hd), cd),
                KV.PlaneSpec("v", n_apps, (hkv, hd), cd)),
        slot_planes=slot_planes, prefix_cacheable=False)


def init_paged_cache(cfg: ArchConfig, n_total_blocks: int, block_size: int,
                     n_slots: int | None = None,
                     planar: bool = False, mesh=None) -> dict:
    """Descriptor-driven serving cache pytree. Paged planes are shaped
    (L, NB, BS, *token_shape) with NO batch dim — sequences own block
    ids, not rows (serving/kvcache.py BlockManager; physical block 0 is
    the trash block). Slot-resident planes (hybrid/ssm descriptors) are
    shaped (L, n_slots, ...) — `n_slots` is required for those families.
    planar=True stores GQA byte planes (NestedKV on paged blocks).

    Subtree keys match the legacy cache convention so model code is
    layout-agnostic: "attn" (gqa/mla paged planes), "shared" (hybrid's
    paged shared-attention planes), "ssm" (slot-resident state).

    mesh: commit the pools onto a serving mesh as they are created —
    each plane's placement follows its descriptor role through
    `launch.sharding.paged_cache_spec` (GQA planes KV-head-sharded when
    divisible, MLA latents/conv_bc replicated, SSM state head-sharded).
    None keeps today's single-device arrays."""
    desc = cache_descriptor(cfg, planar=planar)
    out: dict[str, Any] = {}
    if desc.planes:
        key = "shared" if desc.kind == "hybrid" else "attn"
        out[key] = {
            p.name: jnp.zeros((p.n_layers, n_total_blocks, block_size)
                              + p.token_shape, jnp.dtype(p.dtype))
            for p in desc.planes}
    if desc.slot_planes:
        if n_slots is None:
            raise ValueError(f"{desc.kind} descriptor has slot-resident "
                             "state; init_paged_cache needs n_slots")
        out["ssm"] = {
            p.name: jnp.zeros((p.shape[0], n_slots) + tuple(p.shape[1:]),
                              jnp.dtype(p.dtype))
            for p in desc.slot_planes}
    if mesh is not None:
        from repro.launch import sharding as SH
        shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), out)
        out = jax.device_put(
            out, SH.tree_shardings(shapes, mesh, SH.paged_cache_spec, cfg))
    return out


def planarize_cache(caches: dict) -> dict:
    """Convert prefilled f16 GQA caches ({"k","v"}) into byte-planar form
    (NestedKV). Applied to the self-attention subtrees only; MLA latents
    and cross-attention memories keep their formats."""
    from repro.core.nestedfp import split_bytes

    def conv(sub):
        if isinstance(sub, dict) and set(sub) == {"k", "v"}:
            k_hi, k_lo = split_bytes(sub["k"])
            v_hi, v_lo = split_bytes(sub["v"])
            return {"k_hi": k_hi, "k_lo": k_lo, "v_hi": v_hi, "v_lo": v_lo}
        return sub

    out = dict(caches)
    for key in ("attn", "shared"):
        if key in out:
            out[key] = conv(out[key])
    return out


def encdec_enc_len(dec_len: int) -> int:
    """Encoder (audio-frame) length policy for seamless: seq//8, min 64."""
    return max(64, dec_len // 8)


# ---------------------------------------------------------------------------
# whole-model params
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def window_schedule(cfg: ArchConfig) -> jnp.ndarray | None:
    """Per-layer window array: gemma3 5:1 pattern — every swa_pattern-th
    layer is global (-1), the rest local (sliding_window)."""
    if cfg.sliding_window is None:
        return None
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % cfg.swa_pattern) == (cfg.swa_pattern - 1)
    return jnp.where(is_global, -1, cfg.sliding_window).astype(jnp.int32)


def init_params(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    params: dict[str, Any] = {
        "embed": {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, d),
                                            jnp.float32) * 0.02)},
        "final_norm": L.init_rms_norm(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(ks[1], d, cfg.vocab_size)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: init_decoder_block(k, cfg))
    elif fam == "ssm":
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: init_ssm_block(k, cfg))
    elif fam == "hybrid":
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: init_ssm_block(k, cfg))
        params["shared_attn"] = init_decoder_block(ks[3], cfg)
    elif fam == "encdec":
        params["enc_layers"] = _stack_init(
            ks[2], cfg.n_enc_layers, lambda k: init_decoder_block(k, cfg))
        params["layers"] = _stack_init(
            ks[3], cfg.n_layers, lambda k: init_decoder_block(k, cfg, cross=True))
        params["enc_norm"] = L.init_rms_norm(d)

    if cfg.frontend != "none":
        params["frontend_proj"] = L.init_linear(ks[4], cfg.frontend_dim, d)
    if cfg.mtp_heads:
        params["mtp"] = {
            "proj": L.init_linear(ks[5], 2 * d, d),
            "norm": L.init_rms_norm(d),
            "block": init_decoder_block(ks[6], dataclasses.replace(
                cfg, moe=None, d_ff=2 * d)),
        }
    return params


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------

_AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_fraction")


def _zero_aux():
    return {k: jnp.float32(0.0) for k in _AUX_KEYS}


def _acc_aux(acc, aux):
    return {k: acc[k] + aux.get(k, 0.0) for k in _AUX_KEYS}


def _run_hybrid_grouped(rt, stacked, cfg, x, *, phase, positions,
                        kv_len=None, caches=None, shared_params=None,
                        shared_caches=None, paged=None):
    """zamba2 grouped execution: outer scan over n_groups, each group =
    inner scan over attn_every mamba layers + one shared-attention
    application. The shared cache (n_groups, B, Cap, hkv, hd) — or, for
    phase "paged", the block-pooled (n_groups, NB, BS, hkv, hd) planes —
    rides the outer scan's xs/ys, so each group touches only its own
    slice."""
    every = cfg.attn_every
    n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    n_groups = n_layers // every
    grouped = jax.tree.map(
        lambda p: p.reshape(n_groups, every, *p.shape[1:]), stacked)

    def group_body(carry, xs):
        h, aux_acc = carry

        def layer_body(hh, lx):
            # NOTE: seq_shard_hint was tried here (§Perf Z3) and REFUTED:
            # SSD scan + causal conv consume the full sequence, so GSPMD
            # must all-gather the hint right back (1.96 s -> 4.42 s).
            hh, new_c = apply_ssm_block(rt, lx["p"], cfg, hh, phase=phase,
                                        cache=lx.get("c"), kv_len=kv_len)
            return hh, ({"c": new_c} if new_c is not None else {})

        inner_xs = {"p": xs["p"]}
        if "c" in xs:
            inner_xs["c"] = xs["c"]
        h, inner_ys = jax.lax.scan(layer_body, h, inner_xs)

        ys = dict(inner_ys) if isinstance(inner_ys, dict) else {}
        if phase == "train":
            h, _, _, _ = apply_decoder_block(rt, shared_params, cfg, h,
                                             phase="train",
                                             positions=positions)
        else:
            h, new_shared, _, _ = apply_decoder_block(
                rt, shared_params, cfg, h, phase=phase, positions=positions,
                cache=xs.get("s"), kv_len=kv_len, paged=paged)
            if phase == "prefill":
                # pad (B, S, ...) up to the pre-allocated capacity slice
                def pad_to(full, one):
                    pad = full.shape[1] - one.shape[1]
                    if pad > 0:
                        w = [(0, 0)] * one.ndim
                        w[1] = (0, pad)
                        one = jnp.pad(one, w)
                    return one.astype(full.dtype)
                new_shared = jax.tree.map(pad_to, xs["s"], new_shared)
            ys["s"] = new_shared
        return (h, aux_acc), ys

    xs = {"p": grouped}
    if caches is not None:
        xs["c"] = jax.tree.map(
            lambda c: c.reshape(n_groups, every, *c.shape[1:]), caches)
    if shared_caches is not None and phase != "train":
        xs["s"] = shared_caches
    (x, aux), ys = jax.lax.scan(
        jax.checkpoint(group_body) if phase == "train" else group_body,
        (x, _zero_aux()), xs)
    new_caches = None
    if "c" in ys:
        new_caches = jax.tree.map(
            lambda c: c.reshape(n_layers, *c.shape[2:]), ys["c"])
    return x, new_caches, ys.get("s"), aux


def run_decoder_stack(rt, stacked, cfg, x, *, phase, positions, kv_len=None,
                      caches=None, memory=None, cross_caches=None,
                      causal=True, paged=None, paged_groups=None):
    """Scan the main decoder stack. caches/cross_caches are stacked (L, ...).

    paged_groups: (L,) layer -> window-group map. When given, `paged`
    carries PER-GROUP physical index arrays (phys_write (G, B, C),
    phys_read (G, B, Cap)) and each scanned layer gathers/scatters
    through its own group's block table — the mechanism that lets
    gemma3 local layers read only their sliding window's blocks while
    global layers read the full table."""
    windows = window_schedule(cfg)
    n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]

    def body(carry, xs):
        h, aux_acc = carry
        p = xs["p"]
        pg = paged
        if paged is not None and "g" in xs:
            gi = xs["g"]
            pg = (paged[0][gi], paged[1][gi], paged[2])
        # (seq_shard_hint tried here too — refuted, §Perf Z3/P1: the flash
        # KV scan needs the full sequence per device.)
        h, new_c, new_cross, aux = apply_decoder_block(
            rt, p, cfg, h, phase=phase, positions=positions,
            window=xs.get("w"), cache=xs.get("c"), kv_len=kv_len,
            memory=memory, cross_cache=xs.get("x"), causal=causal,
            paged=pg)
        ys = {}
        if new_c is not None:
            ys["c"] = new_c
        if new_cross is not None:
            ys["x"] = new_cross
        return (h, _acc_aux(aux_acc, aux)), ys

    xs = {"p": stacked}
    if windows is not None:
        xs["w"] = windows
    if paged is not None and paged_groups is not None:
        xs["g"] = jnp.asarray(paged_groups, jnp.int32)
    if caches is not None:
        xs["c"] = caches
    if cross_caches is not None:
        xs["x"] = cross_caches

    fn = jax.checkpoint(body) if phase == "train" else body
    (x, aux), ys = jax.lax.scan(fn, (x, _zero_aux()), xs)
    return x, ys.get("c"), ys.get("x"), aux


def run_ssm_stack(rt, stacked, cfg, x, *, phase, positions, kv_len=None,
                  caches=None, shared_params=None, shared_caches=None,
                  paged=None):
    """Mamba2 stack; zamba2 interleaves the shared attention block.

    When attn_every divides n_layers the hybrid path uses a GROUPED outer
    scan (inner scan over attn_every mamba layers, shared attention once
    per group, shared cache as per-group scan xs/ys). The naive
    cond-in-carry formulation forced GSPMD to rematerialize the whole
    shared KV cache on every one of the 54 layers — 373 s of collectives
    at prefill_32k vs 0.9 s after this restructure (EXPERIMENTS.md §Perf
    iteration Z1)."""
    n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    hybrid = shared_params is not None
    if hybrid and cfg.attn_every and n_layers % cfg.attn_every == 0:
        return _run_hybrid_grouped(rt, stacked, cfg, x, phase=phase,
                                   positions=positions, kv_len=kv_len,
                                   caches=caches,
                                   shared_params=shared_params,
                                   shared_caches=shared_caches, paged=paged)
    if hybrid and phase == "paged":
        raise NotImplementedError(
            "paged hybrid serving requires attn_every | n_layers "
            "(grouped execution); no assigned arch hits this")

    def body(carry, xs):
        h, shared_c, aux_acc = carry
        h, new_c = apply_ssm_block(rt, xs["p"], cfg, h, phase=phase,
                                   cache=xs.get("c"), kv_len=kv_len)
        if hybrid:
            li = xs["i"]
            app_idx = li // cfg.attn_every
            is_app = (li % cfg.attn_every) == (cfg.attn_every - 1)

            def with_attn(h, shared_c):
                if phase == "train":
                    h2, _, _, _ = apply_decoder_block(
                        rt, shared_params, cfg, h, phase="train",
                        positions=positions)
                    return h2, shared_c
                if phase == "prefill":
                    h2, new_cache, _, _ = apply_decoder_block(
                        rt, shared_params, cfg, h, phase="prefill",
                        positions=positions)
                    # write (B,S,...) into the pre-allocated capacity slot
                    new_shared = jax.tree.map(
                        lambda full, one: jax.lax.dynamic_update_slice(
                            full, one[None].astype(full.dtype),
                            (app_idx,) + (0,) * (full.ndim - 1)),
                        shared_c, new_cache)
                    return h2, new_shared
                layer_cache = jax.tree.map(lambda c: c[app_idx], shared_c)
                h2, new_cache, _, _ = apply_decoder_block(
                    rt, shared_params, cfg, h, phase=phase,
                    positions=positions, cache=layer_cache, kv_len=kv_len)
                new_shared = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_index_in_dim(
                        full, one.astype(full.dtype), app_idx, 0),
                    shared_c, new_cache)
                return h2, new_shared

            h, shared_c = jax.lax.cond(
                is_app, with_attn, lambda h, sc: (h, sc), h, shared_c)
        ys = {"c": new_c} if new_c is not None else {}
        return (h, shared_c, aux_acc), ys

    xs = {"p": stacked, "i": jnp.arange(n_layers)}
    if caches is not None:
        xs["c"] = caches
    fn = jax.checkpoint(body) if phase == "train" else body
    (x, shared_caches, aux), ys = jax.lax.scan(
        fn, (x, shared_caches if hybrid else 0, _zero_aux()), xs)
    return x, ys.get("c"), shared_caches if hybrid else None, aux


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(rt, params, cfg, tokens):
    return params["embed"]["tok"].astype(rt.dtype)[tokens]


def lm_logits(rt, params, cfg, h):
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(rt.dtype)
        return jax.lax.dot_general(h, w, (((h.ndim - 1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    return L.apply_linear(
        dataclasses.replace(rt, dtype=jnp.float32), params["lm_head"], h)


def _frontend_tokens(rt, params, cfg, batch):
    """Prepend stub-frontend embeddings (vlm patches / audio frames)."""
    emb = batch["patch_embeds"] if cfg.frontend == "vision" else batch["frames"]
    return L.apply_linear(rt, params["frontend_proj"], emb.astype(rt.dtype))


# ---------------------------------------------------------------------------
# phase entry points
# ---------------------------------------------------------------------------

def backbone(rt, params, cfg, h, *, phase, positions, kv_len=None,
             caches=None, memory=None):
    """Run the appropriate stack; returns (h, new_caches, aux)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        x, c, _, aux = run_decoder_stack(
            rt, params["layers"], cfg, h, phase=phase, positions=positions,
            kv_len=kv_len, caches=None if caches is None else caches["attn"])
        new_caches = None if c is None else {"attn": c}
    elif fam in ("ssm", "hybrid"):
        shared_p = params.get("shared_attn")
        shared_c = None if caches is None else caches.get("shared")
        if fam == "hybrid" and shared_c is None and phase != "train":
            raise ValueError("hybrid prefill/decode needs pre-allocated "
                             "shared-attention caches (see prefill())")
        x, c, sh, aux = run_ssm_stack(
            rt, params["layers"], cfg, h, phase=phase, positions=positions,
            kv_len=kv_len, caches=None if caches is None else caches["ssm"],
            shared_params=shared_p, shared_caches=shared_c)
        new_caches = None
        if c is not None:
            new_caches = {"ssm": c}
            if sh is not None:
                new_caches["shared"] = sh
    elif fam == "encdec":
        x, c, cross, aux = run_decoder_stack(
            rt, params["layers"], cfg, h, phase=phase, positions=positions,
            kv_len=kv_len, caches=None if caches is None else caches["attn"],
            memory=memory,
            cross_caches=None if caches is None else caches.get("cross"))
        new_caches = None
        if c is not None:
            new_caches = {"attn": c, "cross": cross}
    else:
        raise ValueError(fam)
    return x, new_caches, aux


def encode_memory(rt, params, cfg, frames):
    """encdec: run the (bidirectional) encoder over stub frame embeddings."""
    h = _frontend_tokens(rt, params, cfg, {"frames": frames})
    pos = jnp.arange(h.shape[1])[None, :]
    h, _, _, _ = run_decoder_stack(rt, params["enc_layers"], cfg, h,
                                   phase="train", positions=pos, causal=False)
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def train_loss(rt, params, cfg, batch):
    """batch: {"tokens": (B, S+1)} + frontend extras. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    b, s = inp.shape
    h = embed_tokens(rt, params, cfg, inp)
    memory = None
    n_prefix = 0
    if cfg.family == "encdec":
        memory = encode_memory(rt, params, cfg, batch["frames"])
    elif cfg.frontend == "vision":
        front = _frontend_tokens(rt, params, cfg, batch)
        n_prefix = front.shape[1]
        h = jnp.concatenate([front, h], axis=1)
    positions = jnp.arange(h.shape[1])[None, :]
    h, _, aux = backbone(rt, params, cfg, h, phase="train",
                         positions=positions, memory=memory)
    h = h[:, n_prefix:]
    logits = lm_logits(rt, params, cfg, h)              # (B, S, V) f32

    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    zloss = 1e-4 * (logz ** 2).mean()
    loss = ce + zloss + aux["moe_lb_loss"] + aux["moe_z_loss"]
    if cfg.mtp_heads and "mtp" in params:
        loss = loss + 0.1 * _mtp_loss(rt, params, cfg, h, tokens, n_prefix)
    metrics = {"loss": loss, "ce": ce,
               "acc": (logits.argmax(-1) == labels).mean(),
               **{k: aux[k] for k in aux}}
    return loss, metrics


def _mtp_loss(rt, params, cfg, h, tokens, n_prefix):
    """DeepSeek-V3 single-depth multi-token prediction: predict t+2 from
    [h_t ; emb(t+1)] through one extra block (arXiv:2412.19437 §2.2)."""
    p = params["mtp"]
    emb_next = embed_tokens(rt, params, cfg, tokens[:, 1:-1])   # t+1 emb
    h_in = jnp.concatenate(
        [L.rms_norm(h[:, :-1], p["norm"], cfg.norm_eps), emb_next], axis=-1)
    h2 = L.apply_linear(rt, p["proj"], h_in)
    pos = jnp.arange(h2.shape[1])[None, :]
    mtp_cfg = dataclasses.replace(cfg, moe=None, d_ff=2 * cfg.d_model)
    h2, _, _, _ = apply_decoder_block(rt, p["block"], mtp_cfg, h2,
                                      phase="train", positions=pos)
    logits = lm_logits(rt, params, cfg, h2)
    labels = tokens[:, 2:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def prefill(rt, params, cfg, batch, *, capacity: int | None = None,
            logit_position: int | None = None):
    """Process the full prompt; returns (logits, caches, length).

    Logits are taken at `logit_position` (default: last position — the
    engine passes prompt_len-1 when prompts are right-padded to a bucket).
    batch: {"tokens": (B, S)} + frontend extras."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(rt, params, cfg, tokens)
    memory = None
    n_prefix = 0
    if cfg.family == "encdec":
        memory = encode_memory(rt, params, cfg, batch["frames"])
    elif cfg.frontend == "vision":
        front = _frontend_tokens(rt, params, cfg, batch)
        n_prefix = front.shape[1]
        h = jnp.concatenate([front, h], axis=1)
    total = h.shape[1]
    capacity = capacity or total
    positions = jnp.arange(total)[None, :]
    caches_in = (init_cache(cfg, b, capacity) if cfg.family == "hybrid"
                 else None)
    h, caches, _ = backbone(rt, params, cfg, h, phase="prefill",
                            positions=positions, memory=memory,
                            caches=caches_in)
    if logit_position is None:
        hsel = h[:, total - 1: total]
    else:
        # logit_position may be a traced scalar (the engine passes it as an
        # argument so its jit cache keys on (mode, bucket) alone — a static
        # slice here forced one recompile per distinct prompt length)
        pos = jnp.asarray(n_prefix + logit_position, jnp.int32)
        hsel = jax.lax.dynamic_slice_in_dim(h, pos, 1, axis=1)
    logits = lm_logits(rt, params, cfg, hsel)[:, 0]

    # pad prefill KV caches out to capacity
    if caches is not None and "attn" in caches:
        def pad_cache(c):
            pad = capacity - c.shape[2]
            if pad <= 0:
                return c[:, :, :capacity].astype(CACHE_DTYPE)
            w = [(0, 0)] * c.ndim
            w[2] = (0, pad)
            return jnp.pad(c, w).astype(CACHE_DTYPE)
        caches = dict(caches)
        caches["attn"] = jax.tree.map(pad_cache, caches["attn"])
    return logits, caches, total


def paged_step(rt, params, cfg, tokens, caches, block_tables, *,
               q_offset, kv_len, block_size: int, logit_position=None,
               slot=None, return_logits: bool = False,
               sample_all: bool = False):
    """One step over a descriptor-shaped paged cache — covers BOTH
    batched decode (C=1 across all rows) and chunked prefill (a batch of
    ragged right-padded chunk rows, C=chunk bucket) for every
    engine-served family: GQA K/V planes, MLA `c_kv`+`k_rope` latent
    planes (absorbed attention), and hybrid/ssm stacks whose paged
    shared-attention planes pair with slot-resident SSM state.

    tokens:       (B, C) int32, right-padded chunks (GQA/MLA only —
                  recurrent state would absorb pads, so ssm/hybrid
                  chunks are exact-length).
    block_tables: (B, MB) or (G, B, MB) int32 physical block ids in
                  logical order (holes = trash block 0). G is the
                  descriptor's window-group count (gemma3: group 0
                  global, group 1 local) — each layer scatters/gathers
                  through ITS group's table, so slide-freed local
                  blocks read as trash (masked by the window) while
                  global layers see the full history. A (B, MB) table
                  is broadcast to every group (the no-reclamation
                  layout: all groups share one physical block set).
    q_offset:     (B,) absolute position of tokens[:, 0].
    kv_len:       (B,) valid cache tokens AFTER this chunk is written,
                  i.e. q_offset + real_chunk_len (0 disables a row:
                  all its paged writes go to the trash block and its
                  slot-resident state is kept verbatim).
    logit_position: (B,) column of the last real token per row (traced —
                  one compile per (mode, C) regardless of chunk fill).
    slot:         traced scalar slot index for single-row chunks of
                  families with slot-resident state: the chunk reads and
                  writes only that slot's state row (B must be 1).
                  None = caches' slot axis matches B (batched decode).
    return_logits: False (default) fuses greedy sampling into the step
                  and returns (next_ids (B,) int32, new caches) — the
                  engine's one-dispatch hot path pulls B int32s back to
                  host instead of a (B, vocab) float matrix. True is the
                  escape hatch for tests/tools that inspect logits.
    sample_all:   True returns the greedy argmax at EVERY chunk column —
                  (B, C) int32 (or (B, C, V) logits with return_logits)
                  instead of the single `logit_position` selection. This
                  is the speculative-decoding verification mode: column
                  j's argmax is the greedy continuation after consuming
                  the chunk up to j, so the engine's fused accept-select
                  can take the longest draft prefix the model confirms
                  without any extra dispatch. Per-column values are
                  bit-identical to what C=1 decode at that position
                  produces (row/column-parallel GEMMs + per-query paged
                  attention — same property the chunked-prefill fusion
                  relies on).

    Returns (next_ids (B,) int32 | logits (B, V), new caches). Pad
    columns write to the trash block and their outputs are never read;
    chunked and monolithic prefill therefore produce bit-identical
    logits for real tokens (attention families — SSD state rounding is
    chunk-boundary-dependent for ssm/hybrid).

    Block tables may alias: several rows (or several sequences across
    steps) may point at the SAME physical blocks — COW prefix caching
    shares full prompt-prefix blocks read-only. Reads gather keys per
    row in logical order via `phys_read`, so sharing is transparent
    here and in the planar decode kernel; the caller (engine/kvcache)
    guarantees writes only ever target unshared blocks by COW-forking
    before the step runs.
    """
    fam = cfg.family
    if fam == "encdec":
        raise ValueError("paged_step serves decoder-only archs")
    b, c = tokens.shape
    desc = cache_descriptor(cfg)
    ngrp = len(desc.group_windows)
    tables = jnp.asarray(block_tables, jnp.int32)
    if tables.ndim == 2:
        tables = tables[None]
    if tables.shape[0] != ngrp:            # shared table for every group
        tables = jnp.broadcast_to(tables, (ngrp,) + tables.shape[1:])
    q_offset = jnp.asarray(q_offset, jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32)
    mb = tables.shape[2]
    positions = q_offset[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    real = positions < kv_len[:, None]
    blkidx = jnp.clip(positions // block_size, 0, mb - 1)
    blk = jnp.take_along_axis(                              # (G, B, C)
        tables, jnp.broadcast_to(blkidx, (ngrp,) + blkidx.shape), axis=2)
    trash = jnp.arange(c, dtype=jnp.int32)[None, None, :] % block_size
    phys_write = jnp.where(real[None],
                           blk * block_size + (positions % block_size)[None],
                           trash)
    offs = jnp.arange(block_size, dtype=jnp.int32)
    phys_read = (tables[..., None] * block_size
                 + offs[None, None, None, :]).reshape(ngrp, b,
                                                      mb * block_size)

    h = embed_tokens(rt, params, cfg, tokens)
    if fam in ("dense", "moe", "vlm"):
        if ngrp == 1:
            paged = (phys_write[0], phys_read[0], q_offset)
            gmap = None
        else:
            paged = (phys_write, phys_read, q_offset)
            gmap = desc.layer_group_map(cfg.n_layers)
        h, new_attn, _, aux = run_decoder_stack(
            rt, params["layers"], cfg, h, phase="paged", positions=positions,
            kv_len=kv_len, caches=caches["attn"], paged=paged,
            paged_groups=gmap)
        new_caches = {"attn": new_attn}
    else:                                            # ssm / hybrid
        ssm_in = caches["ssm"]
        if slot is not None:
            ssm_in = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                ssm_in)
        h, new_ssm, new_shared, aux = run_ssm_stack(
            rt, params["layers"], cfg, h, phase="paged",
            positions=positions, kv_len=kv_len, caches=ssm_in,
            shared_params=params.get("shared_attn"),
            shared_caches=caches.get("shared"),
            paged=(phys_write[0], phys_read[0], q_offset))
        if slot is not None:
            new_ssm = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1),
                caches["ssm"], new_ssm)
        new_caches = {"ssm": new_ssm}
        if new_shared is not None:
            new_caches["shared"] = new_shared
    if sample_all:
        logits = lm_logits(rt, params, cfg, h)       # (B, C, V)
        if return_logits:
            return logits, new_caches
        return jnp.argmax(logits, -1).astype(jnp.int32), new_caches
    if logit_position is None:
        hsel = h[:, -1:]
    else:
        lp = jnp.asarray(logit_position, jnp.int32)
        hsel = jnp.take_along_axis(h, lp[:, None, None], axis=1)
    logits = lm_logits(rt, params, cfg, hsel)[:, 0]
    if return_logits:
        return logits, new_caches
    return jnp.argmax(logits, -1).astype(jnp.int32), new_caches


def decode_step(rt, params, cfg, tokens, caches, cache_len):
    """One decoding step. tokens: (B, 1); cache_len: scalar or (B,) int32 —
    tokens already in each row's cache. Returns (logits (B,V), caches)."""
    b = tokens.shape[0]
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    h = embed_tokens(rt, params, cfg, tokens)
    positions = lens[:, None]
    h, caches, _ = backbone(rt, params, cfg, h, phase="decode",
                            positions=positions, kv_len=lens + 1,
                            caches=caches)
    return lm_logits(rt, params, cfg, h[:, -1:])[:, 0], caches
