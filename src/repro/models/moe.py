"""Top-k MoE with capacity-based gather/scatter token routing.

TPU-native expert parallelism (DESIGN.md): token activations are sharded
over the data axis, expert weights over the model axis (when n_experts is
divisible; else per-expert d_ff is sharded). Routing uses flat
gather/scatter-add rather than the GShard (T,E,C) dispatch einsum — the
dispatch einsum costs T·E·C·D MXU FLOPs of pure masking (≈ the expert FFN
FLOPs themselves at DeepSeek-V3 scale); gathers move the same bytes with
zero FLOPs. Tokens beyond an expert's capacity are dropped (standard
Switch/GShard semantics, capacity_factor config).

Aux losses: Switch load-balance loss + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime, apply_linear, init_linear


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ep = m.n_experts_padded      # bank rows >= n_experts never routed to
    ks = jax.random.split(key, 5)
    scale = d ** -0.5

    def expert_bank(k):
        return (jax.random.normal(k, (ep, d, m.d_ff_expert),
                                  jnp.float32) * scale)

    p = {
        "router": init_linear(ks[0], d, m.n_experts, scale=scale),
        "w_gate": expert_bank(ks[1]),
        "w_up": expert_bank(ks[2]),
        "w_down": (jax.random.normal(ks[3], (ep, m.d_ff_expert, d),
                                     jnp.float32) * (m.d_ff_expert ** -0.5)),
    }
    if m.n_shared_experts:
        from repro.models.layers import init_swiglu
        p["shared"] = init_swiglu(ks[4], d, m.d_ff_expert * m.n_shared_experts)
    return p


def _has_pod() -> bool:
    from repro.core.compat import get_ambient_mesh
    am = get_ambient_mesh()
    return "pod" in (getattr(am, "axis_names", ()) or ())


def _read_bank(rt: Runtime, w):
    """Expert banks (E,D,F) may be NestedTensors after to_serving().

    fp16 mode reads the lossless reconstruction; fp8 mode reads the upper
    byte dequantized (weight-precision switch — activation quant is applied
    on the dense linears; see DESIGN.md §Precision paths)."""
    from repro.core.nestedfp import NestedTensor, fp8_dequant
    if isinstance(w, NestedTensor):
        if rt.mode == "fp8" and not w.is_exception:
            return fp8_dequant(w.upper, rt.dtype)
        return w.read_f16().astype(rt.dtype)
    return w.astype(rt.dtype)


def _expert_ffn(rt: Runtime, p: dict, xb: jax.Array,
                local: bool = False) -> jax.Array:
    """xb: (G, E, C, D) -> (G, E, C, D), batched-over-experts SwiGLU.

    local=True (small banks): every intermediate is pinned group-local so
    the ONLY resharding is the cheap bank all-gather (§Perf M2)."""
    dt = rt.dtype
    acc = jnp.bfloat16 if rt.fast_accum else jnp.float32

    def pin(t):
        if not local:
            return t
        from repro.models.layers import shard_hint
        d_axes = ("pod", "data") if _has_pod() else "data"
        return shard_hint(t, d_axes, *([None] * (t.ndim - 1)))

    gate = pin(jnp.einsum("gecd,edf->gecf", xb.astype(dt),
                          _read_bank(rt, p["w_gate"]),
                          preferred_element_type=acc))
    up = pin(jnp.einsum("gecd,edf->gecf", xb.astype(dt),
                        _read_bank(rt, p["w_up"]),
                        preferred_element_type=acc))
    h = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
         ).astype(dt)
    return pin(jnp.einsum("gecf,efd->gecd", h, _read_bank(rt, p["w_down"]),
                          preferred_element_type=acc))


def moe_block(rt: Runtime, p: dict, cfg, x: jax.Array
              ) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (B, S, D), aux dict with losses + routing stats.

    GROUPED capacity routing (GShard-style groups = batch rows): every
    sequence routes within its own capacity buffer (G, E_pad, C_g, D), so
    the dispatch scatter is fully LOCAL on data-sharded activations.
    GSPMD then reshapes the g<->e movement into the expert einsum itself —
    all-to-all (big banks, deepseek-v3) or bank all-gather (small banks,
    granite) — instead of all-reducing a global-capacity buffer across the
    data axis on every layer (the flat-T formulation cost 48.5 s/step of
    collectives on granite train_4k; §Perf iteration M1)."""
    m = cfg.moe
    b, s, d = x.shape
    g = b                                                       # groups
    # --- router (f32 for numerics) ---
    logits = apply_linear(rt, p["router"], x).astype(jnp.float32)  # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)       # (G,S,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # --- per-group capacity assignment ---
    cap = max(int(m.top_k * s * m.capacity_factor / m.n_experts), m.top_k)
    ep = m.n_experts_padded
    flat_e = expert_idx.reshape(g, s * m.top_k)                 # (G, S*K)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1               # per-group slot
    slot = jnp.max(pos, axis=-1)                                # (G, S*K)
    keep = slot < cap
    slot_c = jnp.minimum(slot, cap - 1)

    # --- dispatch: LOCAL scatter-add into (G, E_pad, C, D). Dropped tokens
    # contribute masked zeros, so clamped-slot collisions add nothing.
    token_of_choice = jnp.repeat(jnp.arange(s), m.top_k)        # (S*K,)
    vals = (jnp.take_along_axis(x, token_of_choice[None, :, None], axis=1)
            * keep[..., None]).astype(rt.dtype)                 # (G,S*K,D)
    gi = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, ep, cap, d), rt.dtype)
    xb = buf.at[gi, flat_e, slot_c].add(vals)

    # --- expert compute (batched over groups) ---
    # Small banks (granite: 94M params): pin the capacity buffers
    # GROUP-local (data axis) so dispatch/combine never cross devices and
    # the expert einsum all-gathers the (small) banks instead — GSPMD left
    # to itself replicates G and all-reduces partial buffers across data
    # every layer (§Perf M2). Big banks (deepseek-v3) stay consumer-driven
    # (expert-parallel buf + all-to-all).
    bank = p["w_gate"]
    bank_elems = 1
    for dd in getattr(bank, "shape", (0,)):
        bank_elems *= dd
    local = bank_elems * 3 * 4 <= 2 ** 30
    if local:
        from repro.models.layers import shard_hint
        xb = shard_hint(xb, ("pod", "data") if _has_pod() else "data",
                        None, None, None)
    yb = _expert_ffn(rt, p, xb, local=local)                    # (G,E_pad,C,D)

    # --- combine: gather outputs back, weighted by renormalized gates ---
    gathered = yb[gi, flat_e, slot_c]                           # (G,S*K,D)
    w = (gate_vals.reshape(g, -1) * keep).astype(jnp.float32)
    y = jnp.zeros((g, s, d), jnp.float32)
    y = y.at[gi, token_of_choice[None, :].repeat(g, 0)].add(
        gathered.astype(jnp.float32) * w[..., None])

    if m.n_shared_experts:
        from repro.models.layers import swiglu
        y = y + swiglu(rt, p["shared"], x).astype(jnp.float32)

    # --- aux losses (Switch §2.2 + z-loss) ---
    density = jnp.mean(jax.nn.one_hot(expert_idx, m.n_experts,
                                      dtype=jnp.float32), axis=(0, 1, 2))
    router_prob = jnp.mean(probs, axis=(0, 1))                  # (E,)
    lb_loss = m.n_experts * jnp.sum(density * router_prob) * m.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_fraction": dropped}
    return y.reshape(b, s, d).astype(rt.dtype), aux
