"""Multi-head Latent Attention (DeepSeek-V2/V3) [arXiv:2412.19437].

KV cache stores only the compressed latent c_kv (kv_lora_rank) plus the
shared RoPE key (qk_rope_dim) per token — 576 f16 values/token for the
assigned deepseek-v3 config vs 128·256 for vanilla MHA.

Prefill/train materialize per-head K/V from the latent (cheap at O(L));
decode uses the ABSORBED form: W_uk is folded into the query and W_uv into
the output so attention runs entirely in the latent space — per-token
decode cost is H·(r + d_rope) instead of H·L materialization (which would
be petabytes at 32k cache; see DESIGN.md).

    score_h(t) = (q_nope_h W_uk_h^T) · c_kv[t] + q_rope_h · k_rope[t]
    ctx_h      = (Σ_t p_t c_kv[t]) W_uv_h
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (NEG_INF, Runtime, apply_linear, init_linear,
                                 init_rms_norm, rms_norm, rope,
                                 attn_core_prefill, attn_core_train)


def init_mla(key, cfg) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank),
        "q_norm": init_rms_norm(m.q_lora_rank),
        "wq_b": init_linear(ks[1], m.q_lora_rank, h * qk_head),
        "wkv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_dim),
        "kv_norm": init_rms_norm(m.kv_lora_rank),
        "wk_b": init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_dim),
        "wv_b": init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim),
        "wo": init_linear(ks[5], h * m.v_head_dim, d),
    }


def _project_q(rt, p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = apply_linear(rt, p["wq_b"],
                     rms_norm(apply_linear(rt, p["wq_a"], x), p["q_norm"],
                              cfg.norm_eps))
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(rt, p, cfg, x, positions):
    m = cfg.mla
    kv = apply_linear(rt, p["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope       # (B,S,r), (B,S,d_rope)


def _absorbed_weights(p, m, h):
    """W_uk / W_uv in absorbed form: (r, H, d) f32."""
    wk_b = p["wk_b"].weight.read_f16() if hasattr(p["wk_b"], "weight") \
        else p["wk_b"]["w"]
    wv_b = p["wv_b"].weight.read_f16() if hasattr(p["wv_b"], "weight") \
        else p["wv_b"]["w"]
    wk_b = wk_b.astype(jnp.float32).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    wv_b = wv_b.astype(jnp.float32).reshape(m.kv_lora_rank, h, m.v_head_dim)
    return wk_b, wv_b


def _absorbed_attend(q_nope, q_rope, c_kv, k_rope, wk_b, wv_b, m, mask):
    """Absorbed latent-space attention for C query tokens over a latent
    cache of Cap tokens. q_*: (B,C,H,·); c_kv: (B,Cap,r);
    k_rope: (B,Cap,d_rope); mask: (B,C,Cap) bool. Returns (B,C,H,dv)."""
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), wk_b)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_abs, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scores = jnp.where(mask[:, None], (s_lat + s_rope) * scale, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv.astype(jnp.float32))
    return jnp.einsum("bqhr,rhd->bqhd", ctx_lat, wv_b)


def mla_attention(rt: Runtime, p: dict, cfg, x: jax.Array, *, phase: str,
                  positions, cache: dict | None = None, kv_len=None,
                  paged=None):
    """cache: {"c_kv": (B,Cap,r), "k_rope": (B,Cap,d_rope)} (fixed-slot
    decode), or block-pooled planes {"c_kv": (NB,BS,r), "k_rope":
    (NB,BS,d_rope)} for phase "paged" (see layers.attention for the
    paged=(phys_write, phys_read, q_offset) contract: the chunk's
    latents are scattered into the pool, then gathered back per row in
    logical order, so COW-shared blocks are transparent here too).
    Phase "paged" covers BOTH chunked prefill and batched decode in the
    ABSORBED form — one arithmetic path, so chunked and monolithic
    prefill produce bit-identical logits."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(rt, p, cfg, x, positions)

    if phase == "paged":
        from repro.models.layers import _as_lens, shard_hint
        # serving-mesh layout: latent planes are replicated (no head
        # axis — launch.sharding.paged_cache_spec), parallelism lives in
        # the HEAD axis of the absorbed attention. Pin the query heads
        # so GSPMD keeps the wq_b column sharding through the einsum
        # chain instead of replicating the per-head score tensors.
        q_nope = shard_hint(q_nope, None, None, "model", None)
        q_rope = shard_hint(q_rope, None, None, "model", None)
        phys_write, phys_read, q_offset = paged
        c_new, kr_new = _project_kv_latent(rt, p, cfg, x, positions)
        wf = phys_write.reshape(-1)
        ckv_f = cache["c_kv"].reshape(-1, m.kv_lora_rank).at[wf].set(
            c_new.reshape(-1, m.kv_lora_rank).astype(cache["c_kv"].dtype))
        kr_f = cache["k_rope"].reshape(-1, m.qk_rope_dim).at[wf].set(
            kr_new.reshape(-1, m.qk_rope_dim).astype(cache["k_rope"].dtype))
        new_cache = {"c_kv": ckv_f.reshape(cache["c_kv"].shape),
                     "k_rope": kr_f.reshape(cache["k_rope"].shape)}
        c_kv = ckv_f[phys_read]                       # (B, Cap, r) logical
        k_rope = kr_f[phys_read]
        lens = _as_lens(kv_len, b)
        cap = c_kv.shape[1]
        qpos = q_offset[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        kpos = jnp.arange(cap, dtype=jnp.int32)
        mask = (kpos[None, None, :] <= qpos[..., None]) \
            & (kpos[None, None, :] < lens[:, None, None])
        wk_b, wv_b = _absorbed_weights(p, m, h)
        o = _absorbed_attend(q_nope, q_rope, c_kv, k_rope, wk_b, wv_b, m,
                             mask)
    elif phase in ("train", "prefill"):
        c_kv, k_rope = _project_kv_latent(rt, p, cfg, x, positions)
        # materialize per-head K/V from the latent
        k_nope = apply_linear(rt, p["wk_b"], c_kv).reshape(b, s, h, m.qk_nope_dim)
        v = apply_linear(rt, p["wv_b"], c_kv).reshape(b, s, h, m.v_head_dim)
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (b, s, h, m.qk_rope_dim))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        core = attn_core_train if phase == "train" else attn_core_prefill
        o = core(q_full, k_full, v)
        new_cache = ({"c_kv": c_kv, "k_rope": k_rope}
                     if phase == "prefill" else None)
    else:  # decode — absorbed latent-space attention
        from repro.models.layers import _as_lens
        lens = _as_lens(kv_len, b)
        rows = jnp.arange(b)
        c_new, kr_new = _project_kv_latent(rt, p, cfg, x, positions)
        c_kv = cache["c_kv"].at[rows, lens - 1].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, lens - 1].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}

        wk_b, wv_b = _absorbed_weights(p, m, h)
        cap = c_kv.shape[1]
        mask = jnp.broadcast_to(
            jnp.arange(cap)[None, None, :] < lens[:, None, None],
            (b, 1, cap))
        o = _absorbed_attend(q_nope, q_rope, c_kv, k_rope, wk_b, wv_b, m,
                             mask)

    o = o.reshape(b, s, h * m.v_head_dim).astype(rt.dtype)
    return apply_linear(rt, p["wo"], o), new_cache
