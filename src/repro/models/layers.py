"""Shared model building blocks (functional, pytree params, no flax).

Param conventions:
  * every linear is a dict {"w": (K,N)[, "b": (N,)]} in training form, or a
    NestedLinearParams after `to_serving` conversion (core.linear).
  * activations run in `rt.dtype` (bf16 default), matmuls accumulate f32.

Three attention execution paths (see DESIGN.md):
  * attn_train   — materialized scores (train_4k seq fits with remat+microbatch)
  * attn_prefill — blockwise streaming softmax (flash-style lax.scan,
                   forward-only: prefill has no backward pass)
  * attn_decode  — one query vs. a fixed-capacity KV cache
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.linear import NestedLinearParams, nested_linear

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution context threaded through all apply functions."""
    mode: str = "train"          # "train" | "fp16" | "fp8"
    backend: str | None = None   # kernel backend override (ops.py)
    dtype: Any = jnp.bfloat16    # activation dtype
    fast_accum: bool = False     # bf16 cross-shard partial sums (serving
                                 # hillclimb Z4: halves TP all-reduce bytes)
    act_quant: str = "per_tensor"
    # fp8 activation-scale granularity (core.linear): "per_tensor" is the
    # paper's scheme; the serving engine sets "per_token" so each token's
    # fp8 result is independent of what shares the dispatch — continuous
    # batching and speculative C=K+1 chunks reshape the batch every
    # step, and batch-coupled rounding would make generation depend on
    # co-batched requests (and break spec-on/off bit-exactness).
    attn_backend: str | None = None
    # paged-decode attention backend: "pallas" routes single-token paged
    # decode over byte-planar (NestedKV) GQA caches through the
    # scalar-prefetch block-table kernel (interpret-mode off-TPU);
    # None/"ref" keeps the pure-jnp gather path. Orthogonal to `backend`
    # (the GEMM kernel selector) so pallas attention can pair with ref
    # matmuls on CPU.
    mesh: Any = None
    # serving mesh (Engine(mesh=...)): the pure-jnp paths partition via
    # GSPMD from the committed weight/pool shardings, but a pallas_call
    # is opaque to the partitioner — with a mesh, the paged-decode
    # kernel runs under shard_map on per-shard head slices (KV heads
    # divisible by the model axis) and falls back to the ref gather
    # path otherwise. None = single-device serving, byte-for-byte
    # today's behavior.

    @property
    def serving(self) -> bool:
        return self.mode in ("fp16", "fp8")


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def shard_hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op when there is no
    ambient mesh (tests/engine single-device) or any constrained dim does
    not divide its axis. spec entries: None / axis name / tuple of names."""
    from repro.core.compat import get_ambient_mesh
    am = get_ambient_mesh()
    names = getattr(am, "axis_names", ()) or ()
    if not names or len(spec) != x.ndim:
        return x
    for dim, s in zip(x.shape, spec):
        if s is None:
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = 1
        for a in axes:
            if a not in names:
                return x
            size *= am.shape[a]
        if dim % size != 0:
            return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def seq_shard_hint(x: jax.Array, axis: int = 1) -> jax.Array:
    """Megatron-style sequence-parallel hint (REFUTED for this codebase —
    §Perf Z3: flash/SSD scans need the full sequence; kept for reference)."""
    spec = [None] * x.ndim
    spec[axis] = "model"
    return shard_hint(x, *spec)


def apply_linear(rt: Runtime, p, x: jax.Array) -> jax.Array:
    """Dispatch a linear layer: plain (training) or NestedFP (serving)."""
    if isinstance(p, NestedLinearParams):
        mode = "fp8" if rt.mode == "fp8" else "fp16"
        return nested_linear(p, x, mode=mode, backend=rt.backend,
                             out_dtype=rt.dtype, fast_accum=rt.fast_accum,
                             act_quant=rt.act_quant)
    y = jax.lax.dot_general(
        x.astype(rt.dtype), p["w"].astype(rt.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if "b" in p and p["b"] is not None:
        y = y + p["b"]
    return y.astype(rt.dtype)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: float | None = None, dtype=jnp.float32) -> dict:
    scale = d_in ** -0.5 if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,). Split-half convention."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B,S,half)
    cos = jnp.cos(ang)[..., None, :]                            # (B,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def swiglu(rt: Runtime, p: dict, x: jax.Array) -> jax.Array:
    gate = apply_linear(rt, p["gate"], x)
    up = apply_linear(rt, p["up"], x)
    return apply_linear(rt, p["down"], jax.nn.silu(gate) * up)


def init_swiglu(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": init_linear(k1, d_model, d_ff),
            "up": init_linear(k2, d_model, d_ff),
            "down": init_linear(k3, d_ff, d_model)}


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _apply_window(mask, qpos, kpos, window):
    """window: None (global), python int, or traced int scalar where
    values <= 0 mean global (lets a scanned per-layer window array drive
    the gemma3 5:1 local:global pattern)."""
    if window is None:
        return mask
    local = kpos > qpos - window
    return mask & jnp.where(jnp.asarray(window) > 0, local, True)


def _causal_window_mask(sq: int, sk: int, q_offset, window):
    """(sq, sk) boolean mask. q position i (global i+q_offset) may see key j
    iff j <= i+q_offset and j is within the local window (if any)."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    return _apply_window(m, qpos, kpos, window)


def _grouped_scores(q, k):
    """q: (B,Sq,Hkv,G,D), k: (B,Sk,Hkv,D) -> (B,Hkv,G,Sq,Sk) f32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def attn_core_train(q, k, v, *, q_offset=0, window=None, kv_len=None,
                    cross: bool = False, causal: bool = True):
    """Materialized-scores attention. q: (B,Sq,H,Dq), k/v: (B,Sk,Hkv,·)."""
    b, sq, h, dq = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dq) * (dq ** -0.5)
    s = _grouped_scores(qg, k)
    if not cross and causal:
        mask = _causal_window_mask(sq, sk, q_offset, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:   # restrict to valid cache prefix
        s = jnp.where(jnp.arange(sk)[None, None, None, None] < kv_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1])


def attn_core_prefill(q, k, v, *, q_offset=0, window=None, block_k=1024,
                      cross: bool = False):
    """Flash-style streaming softmax over KV blocks (forward only).

    Avoids materializing (Sq, Sk) scores — required for prefill_32k where
    a dense scores tensor is petabytes (DESIGN.md)."""
    b, sq, h, dq = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (sk + pad) // block_k
    qg = (q.reshape(b, sq, hkv, g, dq) * (dq ** -0.5)).astype(jnp.float32)
    kb = k.reshape(b, nb, block_k, hkv, dq).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_k, hkv, dv).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(sq)[:, None] + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, bi = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(jnp.float32))
        kpos = bi * block_k + jnp.arange(block_k)[None, :]
        mask = kpos <= qpos if not cross else (kpos < sk) | (qpos >= 0)
        if not cross:
            mask = _apply_window(mask, qpos, kpos, window)
        mask &= kpos < sk                                 # strip K padding
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)


def _as_lens(kv_len, b):
    """Normalize kv_len to per-row (B,) int32 (scalar broadcasts)."""
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.broadcast_to(kv_len, (b,))
    return kv_len


def attn_core_paged(q, k, v, *, q_offset, kv_len, window=None):
    """Chunked attention over a block-paged cache. q: (B,C,H,Dq) — C query
    tokens per row (decode is the C=1 special case); k/v: (B,Cap,Hkv,·)
    gathered from the physical pool in LOGICAL order via a block table,
    so masking works on logical positions. q_offset: (B,) absolute
    position of each row's first query; kv_len: (B,) valid keys per row
    (the chunk's own k/v are already written). Positions beyond kv_len
    hold trash-block garbage and are masked."""
    b, c, h, dq = q.shape
    cap, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = (q.reshape(b, c, hkv, g, dq) * (dq ** -0.5)).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    qpos = q_offset[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    kpos = jnp.arange(cap, dtype=jnp.int32)
    mask = kpos[None, None, :] <= qpos[..., None]           # (B,C,Cap) causal
    mask &= kpos[None, None, :] < kv_len[:, None, None]
    mask = _apply_window(mask, qpos[..., None], kpos[None, None, :], window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, c, h, v.shape[-1])


def attn_core_decode(q, k_cache, v_cache, kv_len, *, window=None):
    """One query token vs. fixed-capacity cache. q: (B,1,H,D),
    k/v_cache: (B,Cap,Hkv,·), kv_len: scalar or (B,) — per-row valid
    prefix length (the new token's k/v already written at kv_len-1)."""
    b, _, h, dq = q.shape
    cap, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    # scores are (B, Hkv, G, 1, Cap) — the mask must be rank-5 so the batch
    # dim cannot silently align with Hkv under broadcasting
    lens = _as_lens(kv_len, b)[:, None, None, None, None]
    qg = (q.reshape(b, 1, hkv, g, dq) * (dq ** -0.5)).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(cap)[None, None, None, None, :]
    mask = kpos < lens
    mask = _apply_window(mask, lens - 1, kpos, window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# full GQA attention layer (params + apply for all three phases)
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, h * hd, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, hkv * hd, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, hkv * hd, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], h * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _qkv(rt, p, cfg, x, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = apply_linear(rt, p["wq"], x).reshape(b, s, h, hd)
    k = apply_linear(rt, p["wk"], x).reshape(b, s, hkv, hd)
    v = apply_linear(rt, p["wv"], x).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(rt: Runtime, p: dict, cfg, x: jax.Array, *,
              phase: str, positions: jax.Array, window=None,
              cache: dict | None = None, kv_len=None, causal: bool = True,
              paged=None):
    """phase: 'train' | 'prefill' | 'decode' | 'paged'.

    prefill returns (out, new_cache: {k,v} padded to cfg-determined capacity
    handled by caller); decode expects cache dict {k,v} with the write
    already NOT done — this function writes the new kv at kv_len position
    and returns (out, cache).

    paged: (phys_write (B,C), phys_read (B,Cap), q_offset (B,)) flat
    physical indices into the block pool (leaves shaped (NB, BS, Hkv, ·)).
    The chunk's k/v are scattered at phys_write (pad/inactive columns
    point at the trash block), then keys are gathered back in logical
    order via phys_read — so chunked and monolithic prefill see
    bit-identical key tensors. Under sliding-window layer groups
    (gemma3) the caller resolves these PER LAYER from the layer's
    window group's block table (model.run_decoder_stack), so a local
    layer's gather only touches its window's resident blocks —
    slide-freed logical positions read trash-block garbage that the
    `window` mask (already excluding kpos <= qpos - window) provably
    never lets into the softmax.
    """
    b = x.shape[0]
    q, k, v = _qkv(rt, p, cfg, x, positions)
    if phase == "train":
        o = attn_core_train(q, k, v, window=window, causal=causal)
        new_cache = None
    elif phase == "prefill":
        o = attn_core_prefill(q, k, v, window=window)
        new_cache = {"k": k, "v": v}
    elif phase == "paged":
        phys_write, phys_read, q_offset = paged

        def flat(a):     # (NB, BS, ...) pool -> (NB*BS, ...) flat view
            return a.reshape(-1, *a.shape[2:])

        wf = phys_write.reshape(-1)
        if "k_hi" in cache:
            # byte-planar NestedKV on paged blocks: write both planes,
            # fp8 mode reads back only the hi plane (half the traffic)
            from repro.core.nestedfp import e5m2_view, join_bytes, split_bytes
            k_hi, k_lo = split_bytes(k)
            v_hi, v_lo = split_bytes(v)
            new_cache = {}
            for name, val in (("k_hi", k_hi), ("k_lo", k_lo),
                              ("v_hi", v_hi), ("v_lo", v_lo)):
                fl = flat(cache[name]).at[wf].set(
                    val.reshape(-1, *val.shape[2:]))
                new_cache[name] = fl.reshape(cache[name].shape)
            hkv = cache["k_hi"].shape[2]
            msz = rt.mesh.shape["model"] \
                if rt.mesh is not None and "model" in rt.mesh.axis_names \
                else 1
            # x.shape[1] == 1 also routes speculative VERIFICATION
            # chunks (C=K+1 per-row drafts) to the ref gather path
            # below — the kernel is single-query-per-row by
            # construction. Speculation therefore still works under
            # attn_backend="pallas", but draftful steps verify through
            # the ref path (kernel-vs-ref rounding ~1e-6), so the
            # bit-exact speculation-on/off sweeps run on the ref
            # backend.
            if rt.attn_backend == "pallas" and x.shape[1] == 1 \
                    and hkv % msz == 0:
                # single-token decode over planar blocks: hand the block
                # table straight to the scalar-prefetch Pallas kernel —
                # no (B, Cap) logical gather is ever materialized. The
                # table is recovered from phys_read (= table ⊗ BS + offs)
                # by striding; the scanned per-layer window rides as a
                # traced (1,) operand so one executable serves a mixed
                # local/global stack. Interpret mode off-TPU keeps the
                # path runnable (and CI-testable) on CPU. Under a
                # serving mesh the kernel runs inside shard_map on
                # per-shard head slices (KV heads over `model`; q heads
                # follow since H = Hkv·G); when kv_heads does not divide
                # the axis the `hkv % msz` guard above routes decode to
                # the GSPMD-partitionable ref gather instead.
                from repro.kernels.planar_decode_attention import (
                    paged_planar_decode_attention)
                bs_tok = cache["k_hi"].shape[1]
                tables = phys_read[:, ::bs_tok] // bs_tok        # (B, MB)
                wa = None
                if window is not None:
                    wa = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
                fp8 = rt.mode == "fp8"
                interp = jax.default_backend() != "tpu"
                if msz > 1:
                    from jax.experimental.shard_map import shard_map
                    # window placeholder must be concrete for shard_map
                    # (0 = global; arithmetic-identical to None)
                    wa0 = wa if wa is not None \
                        else jnp.zeros((1,), jnp.int32)

                    def _local(qq, kh, kl, vh, vl, tb, ln, w):
                        return paged_planar_decode_attention(
                            qq, kh, kl, vh, vl, tb, ln, fp8=fp8,
                            window_arr=w, interpret=interp)
                    pool = P(None, None, "model", None)
                    o = shard_map(
                        _local, mesh=rt.mesh,
                        in_specs=(P(None, "model", None), pool, pool,
                                  pool, pool, P(None, None), P(None),
                                  P(None)),
                        out_specs=P(None, "model", None),
                        check_rep=False)(
                        q[:, 0], new_cache["k_hi"], new_cache["k_lo"],
                        new_cache["v_hi"], new_cache["v_lo"], tables,
                        _as_lens(kv_len, b), wa0)[:, None]
                else:
                    o = paged_planar_decode_attention(
                        q[:, 0], new_cache["k_hi"], new_cache["k_lo"],
                        new_cache["v_hi"], new_cache["v_lo"], tables,
                        _as_lens(kv_len, b), fp8=fp8, window_arr=wa,
                        interpret=interp)[:, None]
                o = o.reshape(b, x.shape[1], -1).astype(rt.dtype)
                return apply_linear(rt, p["wo"], o), new_cache
            if rt.mode == "fp8":
                kc = e5m2_view(flat(new_cache["k_hi"])[phys_read], jnp.float16)
                vc = e5m2_view(flat(new_cache["v_hi"])[phys_read], jnp.float16)
            else:
                kc = join_bytes(flat(new_cache["k_hi"])[phys_read],
                                flat(new_cache["k_lo"])[phys_read])
                vc = join_bytes(flat(new_cache["v_hi"])[phys_read],
                                flat(new_cache["v_lo"])[phys_read])
        else:
            kf = flat(cache["k"]).at[wf].set(
                k.astype(cache["k"].dtype).reshape(-1, *k.shape[2:]))
            vf = flat(cache["v"]).at[wf].set(
                v.astype(cache["v"].dtype).reshape(-1, *v.shape[2:]))
            new_cache = {"k": kf.reshape(cache["k"].shape),
                         "v": vf.reshape(cache["v"].shape)}
            kc, vc = kf[phys_read], vf[phys_read]
        o = attn_core_paged(q, kc, vc, q_offset=q_offset,
                            kv_len=_as_lens(kv_len, b), window=window)
    elif phase == "decode":
        lens = _as_lens(kv_len, b)
        rows = jnp.arange(b)
        if "k_hi" in cache:
            # byte-planar NestedKV (DESIGN.md §8): write both planes; fp8
            # mode READS only the high plane (e5m2 values, half traffic)
            from repro.core.nestedfp import e5m2_view, join_bytes, split_bytes
            k_hi, k_lo = split_bytes(k[:, 0])
            v_hi, v_lo = split_bytes(v[:, 0])
            new_cache = {
                "k_hi": cache["k_hi"].at[rows, lens - 1].set(k_hi),
                "k_lo": cache["k_lo"].at[rows, lens - 1].set(k_lo),
                "v_hi": cache["v_hi"].at[rows, lens - 1].set(v_hi),
                "v_lo": cache["v_lo"].at[rows, lens - 1].set(v_lo),
            }
            if rt.mode == "fp8":
                kc = e5m2_view(new_cache["k_hi"], jnp.float16)
                vc = e5m2_view(new_cache["v_hi"], jnp.float16)
            else:
                kc = join_bytes(new_cache["k_hi"], new_cache["k_lo"])
                vc = join_bytes(new_cache["v_hi"], new_cache["v_lo"])
        else:
            kc = cache["k"].at[rows, lens - 1].set(
                k[:, 0].astype(cache["k"].dtype))
            vc = cache["v"].at[rows, lens - 1].set(
                v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": kc, "v": vc}
        o = attn_core_decode(q, kc, vc, lens, window=window)
    else:
        raise ValueError(phase)
    o = o.reshape(b, x.shape[1], -1).astype(rt.dtype)
    return apply_linear(rt, p["wo"], o), new_cache


# ---------------------------------------------------------------------------
# cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg) -> dict:
    return init_attention(key, cfg)


def cross_attention(rt: Runtime, p: dict, cfg, x: jax.Array,
                    memory: jax.Array | None, *, cache: dict | None = None):
    """Decoder cross-attn. memory: (B, Senc, D) encoder output; when a
    cache dict {k,v} is given, memory projections are reused from it."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = apply_linear(rt, p["wq"], x).reshape(b, s, h, hd)
    if cache is None:
        mk = apply_linear(rt, p["wk"], memory).reshape(b, -1, hkv, hd)
        mv = apply_linear(rt, p["wv"], memory).reshape(b, -1, hkv, hd)
        cache = {"k": mk, "v": mv}
    o = attn_core_train(q, cache["k"], cache["v"], cross=True)
    o = o.reshape(b, s, -1).astype(rt.dtype)
    return apply_linear(rt, p["wo"], o), cache
