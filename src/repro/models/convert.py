"""Convert trained params into NestedFP serving params.

Follows the paper's scope: NestedFP applies to *linear layers* (QKV/O,
MLPs, MoE expert banks, SSM/MLA projections). Embeddings, the LM head,
MoE routers, norms, convs and other 1-D params stay in their original
precision ("Quantization is applied exclusively to linear layers, with
embedding layers left in higher precision", paper §2.2/Table 1 note).

`structural=True` builds the same tree from ShapeDtypeStructs (no data,
applicability assumed) — used by the dry-run's input_specs().
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import NestedLinearParams
from repro.core.nestedfp import NestedTensor

# path substrings excluded from nesting
_EXCLUDE = ("embed", "lm_head", "router", "frontend_proj")
# 3-D expert-bank / projection leaves nested as whole tensors
_BANK_KEYS = ("w_gate", "w_up", "w_down")


def _is_linear_dict(node) -> bool:
    return (isinstance(node, dict) and "w" in node
            and hasattr(node["w"], "ndim") and node["w"].ndim >= 2)


def _nest_tensor(arr, structural: bool) -> NestedTensor:
    if structural:
        shape, = (arr.shape,)
        return NestedTensor(
            upper=jax.ShapeDtypeStruct(shape, jnp.uint8),
            lower=jax.ShapeDtypeStruct(shape, jnp.uint8),
            raw=None)
    return NestedTensor.from_f16(jnp.asarray(arr, jnp.float16))


def to_serving(tree, *, structural: bool = False, path: str = ""):
    """Recursively nest every eligible linear weight."""
    excluded = any(e in path for e in _EXCLUDE)
    if isinstance(tree, dict):
        if _is_linear_dict(tree) and not excluded:
            return NestedLinearParams(
                weight=_nest_tensor(tree["w"], structural),
                bias=tree.get("b"))
        out = {}
        for k, v in tree.items():
            if k in _BANK_KEYS and not excluded and hasattr(v, "ndim"):
                out[k] = _nest_tensor(v, structural)
            else:
                out[k] = to_serving(v, structural=structural,
                                    path=f"{path}/{k}")
        return out
    if isinstance(tree, (list, tuple)):
        return type(tree)(to_serving(v, structural=structural,
                                     path=f"{path}[{i}]")
                          for i, v in enumerate(tree))
    return tree


def serving_memory_bytes(tree) -> dict[str, int]:
    """Audit: bytes of nested vs. raw leaves (paper's zero-overhead claim)."""
    nested = raw = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            if leaf.dtype == jnp.uint8:
                nested += leaf.nbytes
            else:
                raw += leaf.nbytes
    return {"nested_bytes": nested, "other_bytes": raw,
            "total_bytes": nested + raw}
