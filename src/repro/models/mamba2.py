"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of Q tokens;
within a chunk the (quadratic-in-Q) masked-decay score matrix is applied
directly, and a lax.scan carries the (H, P, N) recurrent state across
chunks. Total cost is O(L·Q·H·(N+P)) — sub-quadratic in L, which is what
qualifies the SSM/hybrid archs for the long_500k shape.

Decode is a single recurrence step on a (B, H, P, N) state + a rolling
depthwise-conv cache — O(1) per token regardless of context length.

Recurrence (per head h, diag A):
    S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T        (S: P x N)
    y_t = C_t S_t^T + D x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime, apply_linear, init_linear, init_rms_norm, rms_norm


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_mamba2(key, cfg) -> dict:
    """Input projections are SEPARATE matrices (z / x / BC / dt) rather
    than one fused in_proj: a fused output dim sharded over the model axis
    crosses the z|x|B|C|dt segment boundaries, and GSPMD inserts per-layer
    resharding collectives at every jnp.split (§Perf iteration Z2 — the
    split shaved ~1.6 s/step of collectives off zamba2 prefill_32k).
    x and z shard cleanly over heads; BC and dt are tiny and replicate."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 7)
    gn = 2 * s.n_groups * s.d_state
    dt = jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32)
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    return {
        "in_z": init_linear(ks[0], cfg.d_model, d_inner),
        "in_x": init_linear(ks[5], cfg.d_model, d_inner),
        "in_bc": init_linear(ks[6], cfg.d_model, gn),
        "in_dt": init_linear(ks[1], cfg.d_model, n_heads),
        # depthwise convs split per segment (same boundary argument)
        "conv_wx": (jax.random.normal(ks[1], (s.conv_width, d_inner),
                                      jnp.float32) * (s.conv_width ** -0.5)),
        "conv_bx": jnp.zeros((d_inner,), jnp.float32),
        "conv_wbc": (jax.random.normal(ks[3], (s.conv_width, gn),
                                       jnp.float32) * (s.conv_width ** -0.5)),
        "conv_bbc": jnp.zeros((gn,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),       # softplus^-1(dt)
        "A_log": jnp.log(jnp.ones((n_heads,), jnp.float32)
                         + jax.random.uniform(ks[3], (n_heads,))* 15.0),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": init_rms_norm(d_inner),
        "out_proj": init_linear(ks[4], d_inner, cfg.d_model),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv via width-W shifted adds.

    u: (B, L, C); w: (W, C); state: (B, W-1, C) rolling cache or None.
    Returns (out (B,L,C), new_state (B, W-1, C))."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    full = jnp.concatenate([state, u], axis=1)          # (B, W-1+L, C)
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(width):
        out = out + full[:, i:i + u.shape[1]].astype(jnp.float32) * w[i]
    new_state = full[:, -(width - 1):]
    return jax.nn.silu(out + b).astype(u.dtype), new_state




def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (b,l,h,p)  dt: (b,l,h)  A: (h,) (negative)  B,C: (b,l,g,n)  D: (h,)
    initial_state: (b,h,p,n) f32 carried in from an earlier chunk of the
    same sequence (None = zeros — fresh sequence). Enables chunked
    prefill through the paged engine: each prompt chunk resumes the SSD
    recurrence where the previous chunk's state left off.
    returns y: (b,l,h,p), final state (b,h,p,n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lc = x.shape[1]
    nc = lc // chunk
    rep = h // g                                     # heads per B/C group

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Bh = jnp.repeat(Bc, rep, axis=3)                 # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                                     # (b,nc,q,h) negative
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum

    # intra-chunk: scores_ij = C_i·B_j * exp(cum_i - cum_j) * dt_j, i >= j
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None])        # (b,nc,q,q,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * decay * dtc[:, :, None]
    y = jnp.einsum("bcijh,bcjhp->bcihp", scores,
                   xc.astype(jnp.float32))

    # chunk summary state: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                # (b,nc,q,h)
    chunk_states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                              tail, Bh, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1])                         # (b,nc,h)

    def step(S, inp):
        states_c, decay_c = inp                      # (b,h,p,n), (b,h)
        S_new = S * decay_c[..., None, None] + states_c
        return S_new, S                              # emit state BEFORE chunk

    S0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    S_final, S_prev = jax.lax.scan(
        step, S0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)         # (b,nc,h,p,n)

    # inter-chunk contribution: y_i += exp(cum_i) C_i · S_prev
    y = y + jnp.einsum("bcihn,bchpn->bcihp",
                       Ch * jnp.exp(cum)[..., None], S_prev)
    y = y + D[None, None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(b, lc, h, p)[:, :l]
    return y, S_final


def ssd_decode_step(state, x, dt, A, B, C, D):
    """One-token recurrence. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B,C: (b,g,n). Returns (y (b,h,p), new_state)."""
    g = B.shape[1]
    rep = x.shape[1] // g
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=1)    # (b,h,n)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A)                                  # (b,h)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtf, x.astype(jnp.float32), Bh)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + D[None, :, None] * x
    return y.astype(x.dtype), new_state


def mamba2_block(rt: Runtime, p: dict, cfg, x: jax.Array, *,
                 phase: str, cache: dict | None = None, kv_len=None):
    """x: (B, S, D). cache (decode): {"conv": (B,W-1,C), "ssm": (B,H,P,N)}.

    phase "paged" is the engine's unified chunk/decode entry: S tokens
    continue the recurrence from the slot-resident cache state (decode is
    the S == 1 special case, dispatched to `ssd_decode_step` so batched
    decode stays bit-identical to the fixed-slot decode arithmetic).
    `kv_len` (B,) masks state writes for inactive rows (kv_len == 0):
    the engine batch-decodes all slots, and a row that is mid-prefill or
    empty must not have its state clobbered by garbage tokens.

    Returns (out, new_cache | None (train) | prefill cache)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    b, seq, _ = x.shape

    z = apply_linear(rt, p["in_z"], x)
    xp = apply_linear(rt, p["in_x"], x)
    bc = apply_linear(rt, p["in_bc"], x)
    dt_raw = apply_linear(rt, p["in_dt"], x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    cx = cache["conv_x"] if cache is not None else None
    cb = cache["conv_bc"] if cache is not None else None
    xs, new_cx = _causal_conv(xp, p["conv_wx"], p["conv_bx"], cx)
    bc_conv, new_cb = _causal_conv(bc, p["conv_wbc"], p["conv_bbc"], cb)
    gn = s.n_groups * s.d_state
    B_, C_ = jnp.split(bc_conv, [gn], axis=-1)   # bc_conv: (.., 2*gn)
    xh = xs.reshape(b, seq, n_heads, s.head_dim)
    Bm = B_.reshape(b, seq, s.n_groups, s.d_state)
    Cm = C_.reshape(b, seq, s.n_groups, s.d_state)

    if phase in ("train", "prefill"):
        y, S_final = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], chunk=s.chunk_size)
        new_cache = ({"conv_x": new_cx.astype(jnp.float16),
                      "conv_bc": new_cb.astype(jnp.float16), "ssm": S_final}
                     if phase == "prefill" else None)
    elif phase == "paged":
        if seq == 1:   # batched decode — same arithmetic as fixed-slot decode
            y1, S_new = ssd_decode_step(
                cache["ssm"].astype(jnp.float32), xh[:, 0], dt[:, 0], A,
                Bm[:, 0], Cm[:, 0], p["D"])
            y = y1[:, None]
        else:          # prompt chunk — resume the SSD scan from cache state
            y, S_new = ssd_chunked(xh, dt, A, Bm, Cm, p["D"],
                                   chunk=s.chunk_size,
                                   initial_state=cache["ssm"])
        new_cache = {"conv_x": new_cx.astype(jnp.float16),
                     "conv_bc": new_cb.astype(jnp.float16), "ssm": S_new}
        if kv_len is not None:
            # inactive rows (kv_len == 0) keep their old state verbatim
            from repro.models.layers import _as_lens
            act = _as_lens(kv_len, b) > 0
            new_cache = {
                k: jnp.where(act.reshape((b,) + (1,) * (v.ndim - 1)),
                             v, cache[k].astype(v.dtype))
                for k, v in new_cache.items()}
    else:  # decode: seq == 1
        y1, S_new = ssd_decode_step(
            cache["ssm"].astype(jnp.float32), xh[:, 0], dt[:, 0], A,
            Bm[:, 0], Cm[:, 0], p["D"])
        y = y1[:, None]
        new_cache = {"conv_x": new_cx.astype(jnp.float16),
                     "conv_bc": new_cb.astype(jnp.float16), "ssm": S_new}

    y = y.reshape(b, seq, d_inner).astype(rt.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(rt.dtype),
                 p["norm"], cfg.norm_eps)
    return apply_linear(rt, p["out_proj"], y), new_cache
