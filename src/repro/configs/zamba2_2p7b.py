"""Zamba2-2.7B [arXiv:2411.15242]: hybrid — Mamba2 backbone with a SHARED
full-attention block applied every 6 layers (9 applications over 54L).
The real model's per-invocation LoRA deltas on the shared block are
omitted (DESIGN.md §Arch-applicability)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64),
    attn_every=6,
    max_seq_len=1_048_576,
)
