"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA, MoE 256 routed experts top-8
+ 1 shared, per-expert d_ff=2048, 61L, MTP. All layers MoE here (the real
model's 3 leading dense layers are folded into the MoE stack; see
DESIGN.md)."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=0, vocab_size=129280,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128),
    mtp_heads=1,
)
