"""Phi-3-Vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
decoder (32L) + CLIP vision frontend. The vision tower is a STUB per the
assignment carve-out — input_specs() supplies precomputed patch embeddings
(frontend_dim=1024, 576 patches); the in-model projector maps them to
d_model and they are prepended to the text tokens."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    frontend="vision", frontend_dim=1024, frontend_len=576,
    rope_theta=500000.0,
)
