"""SeamlessM4T-Large-v2 [arXiv:2308.11596]: encoder-decoder; the speech
frontend (mel + conv codec) is a STUB per the assignment carve-out —
input_specs() supplies precomputed frame embeddings (frontend_dim=1024).
24 encoder + 24 decoder layers; decoder cross-attends to encoder memory."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    n_enc_layers=24, frontend="audio", frontend_dim=1024,
)
