"""Config registry: --arch <id> resolution."""
from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES

from repro.configs import (
    qwen3_8b, qwen15_0p5b, deepseek_coder_33b, gemma3_1b, granite_moe_3b,
    deepseek_v3_671b, mamba2_2p7b, zamba2_2p7b, seamless_m4t_v2,
    phi3_vision_4p2b, llama31_8b,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (qwen3_8b, qwen15_0p5b, deepseek_coder_33b, gemma3_1b,
              granite_moe_3b, deepseek_v3_671b, mamba2_2p7b, zamba2_2p7b,
              seamless_m4t_v2, phi3_vision_4p2b, llama31_8b)
}

ASSIGNED = [a for a in ARCHS if a != "llama3.1-8b"]


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]
