"""Llama-3.1-8B — the paper's own primary evaluation model (Table 1/2,
Fig 7/8). Not part of the assigned pool; included so the benchmarks can
reproduce the paper's GEMM shapes (N,K) exactly."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
)
