"""Architecture config system.

One `ArchConfig` instance per assigned architecture (exact values from the
assignment table; sources cited per file). `reduced()` derives the 2-layer
smoke-test variant used by tests/test_arch_smoke.py. `INPUT_SHAPES` are the
four assigned (seq, batch) workload shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # pad the expert BANKS (not the router) up to a multiple of the mesh
    # model axis so expert-parallel sharding divides evenly (granite's 40
    # experts pad to 48 on a 16-wide model axis; MaxText does the same).
    pad_experts_to: int | None = None

    @property
    def n_experts_padded(self) -> int:
        return self.pad_experts_to or self.n_experts


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention [arXiv:2412.19437]."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD [arXiv:2405.21060]."""
    d_state: int
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int           # 0 for attention-free (pure SSM)
    n_kv_heads: int
    d_ff: int              # dense FFN width (0 if all-MoE / attention-free)
    vocab_size: int
    head_dim: int | None = None          # default d_model // n_heads
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None    # window size for local layers
    swa_pattern: int = 0                 # N => 1 global every N layers (gemma3: 6)
    # subsystems
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0                  # hybrid: shared attn block period
    # enc-dec / multimodal
    n_enc_layers: int = 0
    frontend: Literal["none", "audio", "vision"] = "none"
    frontend_dim: int = 0                # embedding dim delivered by the stub
    frontend_len: int = 0                # frames/patches (0 = derived)
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp_heads: int = 0                   # deepseek-v3 multi-token prediction
    max_seq_len: int = 131072

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def is_sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md shape policy)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 or self.attn_every > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoding path

    @property
    def cache_kind(self) -> str:
        """Serving cache-descriptor family (see serving/kvcache.py):
        'gqa' | 'mla' | 'hybrid' | 'ssm' | 'encdec'. All but 'encdec'
        run through the engine's paged scheduling path."""
        if self.family == "encdec":
            return "encdec"
        if self.family in ("ssm", "hybrid"):
            return self.family
        return "mla" if self.mla is not None else "gqa"

    def reduced(self) -> "ArchConfig":
        """2-layer, d_model<=512, <=4 experts variant for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=128,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                pad_experts_to=6 if self.moe.pad_experts_to else None)
        small_mla = None
        if self.mla is not None:
            small_mla = MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                                  qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32)
        small_ssm = None
        if self.ssm is not None:
            small_ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32,
                                            chunk_size=32)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if n_heads else 0
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=256,
            n_heads=n_heads,
            n_kv_heads=max(n_kv, 1) if n_heads else 0,
            head_dim=64 if n_heads else None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=512,
            moe=small_moe, mla=small_mla, ssm=small_ssm,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            swa_pattern=min(self.swa_pattern, 2) if self.swa_pattern else 0,
            # derived from the reduced swa_pattern, and deliberately ODD
            # so the window is never aligned to any KV block size — the
            # paged tests must exercise windows that end mid-block
            # (a fixed 64 was always block-aligned and hid those paths)
            sliding_window=(8 * max(min(self.swa_pattern, 2), 1) + 3)
            if self.sliding_window else None,
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            mtp_heads=self.mtp_heads,
            max_seq_len=4096,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
