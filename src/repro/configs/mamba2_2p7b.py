"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD, 64L, d_state=128."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128),
    max_seq_len=1_048_576,
)
