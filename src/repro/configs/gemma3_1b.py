"""Gemma3-1B [hf:google/gemma-3-1b-pt]: dense, 5:1 local:global sliding
window (window=512 local layers, 1 global layer every 6), 128k context."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=512, swa_pattern=6,
    rope_theta=1_000_000.0, tie_embeddings=True,
    max_seq_len=524288,
)
