"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-*-base family]:
MoE 40 experts top-8, per-expert d_ff=512, GQA kv=8, 32L."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=0, vocab_size=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  pad_experts_to=48),  # 48 % 16 == 0: expert-parallel
)
