"""Production mesh builders.

Single pod : (16, 16)    axes (data, model)  — 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes (pod, data, model) — 512 chips; `pod` is an
             outer data-parallel axis crossing the inter-pod DCN/ICI links.

Defined as functions (not module constants) so importing this module never
touches jax device state; the dry-run forces 512 host devices BEFORE the
first jax call (launch/dryrun.py lines 1-2).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.compat import make_compat_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)}; the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before any jax import")
    return make_compat_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for sharding unit tests (run in a subprocess with a
    forced device count)."""
    need = data * model
    return make_compat_mesh((data, model), ("data", "model"),
                            devices=jax.devices()[:need])


def make_serving_mesh(model: int | None = None):
    """1-D ("model",) tensor-parallel mesh for the paged serving engine
    (`Engine(mesh=...)`). Serving has no data axis — continuous batching
    IS the batch dimension — so every chip holds one model shard and the
    whole mesh advances one engine step together. `model=None` takes all
    local devices; the 4-device CPU debug shape comes from
    `XLA_FLAGS=--xla_force_host_platform_device_count=4` set before the
    first jax import."""
    devs = jax.devices()
    n = len(devs) if model is None else model
    if len(devs) < n:
        raise RuntimeError(
            f"serving mesh needs {n} devices, have {len(devs)}; force the "
            "host device count BEFORE any jax import")
    return make_compat_mesh((n,), ("model",), devices=devs[:n])


def make_replica_meshes(n_replicas: int, model: int):
    """Disjoint 1-D ("model",) mesh slices for data-parallel engine
    replicas behind `serving.router.Router`: replica i owns devices
    [i*model, (i+1)*model). Replication is the ROUTER's job (placement,
    failover), not GSPMD's — each slice is its own single-program mesh,
    so a dead replica's devices take nothing else down with them."""
    devs = jax.devices()
    need = n_replicas * model
    if len(devs) < need:
        raise RuntimeError(
            f"{n_replicas} replica meshes of {model} devices need {need}, "
            f"have {len(devs)}; force the host device count BEFORE any "
            "jax import")
    return [make_compat_mesh((model,), ("model",),
                             devices=devs[i * model:(i + 1) * model])
            for i in range(n_replicas)]


def data_axes(mesh) -> tuple[str, ...]:
    """All batch-parallel axes of a mesh (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    s = 1
    for a in data_axes(mesh):
        s *= mesh.shape[a]
    return s
