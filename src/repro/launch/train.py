"""Training launcher: end-to-end driver for any --arch on the local mesh.

On CPU this trains reduced variants (examples/train_tiny.py trains a
~100M-param model for a few hundred steps); on a real TPU slice the same
code path drives the production mesh via --mesh single|multi.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 100 --batch 8 --seq 128 [--ckpt out/ckpt]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier on the reduced config")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, SyntheticLM, microbatch_split
    from repro.launch.steps import make_train_step
    from repro.models import model as M
    from repro.optim import adamw

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.scale != 1.0:
        cfg = dataclasses.replace(
            cfg, d_model=int(cfg.d_model * args.scale),
            d_ff=int(cfg.d_ff * args.scale) if cfg.d_ff else 0)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)

    print(f"training {cfg.arch_id}: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5))
    opt_state = adamw.init_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    data = SyntheticLM(cfg, DataConfig(seq_len=args.seq,
                                       global_batch=args.batch,
                                       seed=args.seed))
    losses = []
    t0 = time.time()
    for i, batch in enumerate(data.batches(args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        batch = microbatch_split(batch, args.micro)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")

    improved = np.mean(losses[:5]) - np.mean(losses[-5:])
    print(json.dumps({"first5_loss": float(np.mean(losses[:5])),
                      "last5_loss": float(np.mean(losses[-5:])),
                      "improvement": float(improved)}))
    if args.ckpt:
        from repro.checkpoint import io
        io.save(args.ckpt, {"params": params, "opt": opt_state},
                step=args.steps)
        print(f"checkpoint -> {args.ckpt}")
    return 0 if improved > 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
