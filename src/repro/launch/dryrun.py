import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes and extract the
roofline inputs from the compiled artifact.

MUST keep the two lines above FIRST — jax locks the device count at init.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k \
      --mesh single --mode fp16            # one combo, prints + JSON
  python -m repro.launch.dryrun --all [--mesh both]   # orchestrate all
      combos, each in a fresh subprocess (resume-safe; skips existing JSON)
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def run_one(arch_id: str, shape_name: str, mesh_kind: str, mode: str,
            out_dir: str, kv: str = "f16") -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, INPUT_SHAPES
    from repro.launch import mesh as mesh_lib
    from repro.launch import sharding as sh
    from repro.launch import steps
    from repro.optim import adamw
    from repro.roofline import analysis as roof

    cfg = get_arch(arch_id)
    shape = INPUT_SHAPES[shape_name]
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                 "mode": mode, "kv": kv}

    ok, reason = steps.shape_supported(cfg, shape)
    if not ok:
        rec.update({"status": "skipped", "reason": reason})
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    data_sz = mesh_lib.data_axis_size(mesh)
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(low_mem=(cfg.arch_id == "deepseek-v3-671b"))
        params = steps.param_structs(cfg, serving=False)
        opt = steps.opt_structs(cfg, opt_cfg, params)
        batch = steps.batch_specs(cfg, shape, data_size=data_sz)
        p_shard = sh.tree_shardings(params, mesh, sh.param_spec, cfg)
        o_shard = {"step": sh.scalar_sharding(mesh),
                   "m": sh.tree_shardings(opt["m"], mesh, sh.opt_state_spec,
                                          cfg),
                   "v": sh.tree_shardings(opt["v"], mesh, sh.opt_state_spec,
                                          cfg)}
        b_shard = sh.tree_shardings(batch, mesh, sh.batch_spec, cfg, micro=True)
        fn = steps.make_train_step(cfg, opt_cfg)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            ).lower(params, opt, batch)
    elif shape.kind == "prefill":
        params = steps.param_structs(cfg, serving=True)
        batch = steps.batch_specs(cfg, shape, data_size=data_sz)
        p_shard = sh.tree_shardings(params, mesh, sh.param_spec, cfg)
        b_shard = sh.tree_shardings(batch, mesh, sh.batch_spec, cfg)
        fn = steps.make_prefill_step(cfg, mode=mode, capacity=shape.seq_len)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(p_shard, b_shard),
            ).lower(params, batch)
    else:  # decode
        params = steps.param_structs(cfg, serving=True)
        caches = steps.cache_structs(cfg, shape, planar=(kv == "planar"))
        binp = steps.batch_specs(cfg, shape, data_size=data_sz)
        tokens, cache_len = binp["tokens"], binp["cache_len"]
        p_shard = sh.tree_shardings(params, mesh, sh.param_spec, cfg)
        c_shard = sh.tree_shardings(caches, mesh, sh.cache_spec, cfg)
        t_shard = sh.tree_shardings({"tokens": tokens}, mesh, sh.batch_spec,
                                    cfg)["tokens"]
        fn = steps.make_decode_step(cfg, mode=mode)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(p_shard, c_shard, t_shard,
                                  sh.scalar_sharding(mesh)),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params, caches, tokens, cache_len)

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    with jax.set_mesh(mesh):
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis (proves it fits) ----
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    }
    print(f"[memory/device] {json.dumps(rec['memory'])}")

    # ---- cost analysis (per-device; NOTE: XLA counts while bodies once) ----
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops_xla = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))

    # exact global FLOPs from the jaxpr (scan trip counts applied)
    from repro.roofline import flops as fcount
    if shape.kind == "train":
        flops_global = fcount.count_step_flops(fn, params, opt, batch)
        trips = fcount.scan_trip_info(fn, params, opt, batch)
    elif shape.kind == "prefill":
        flops_global = fcount.count_step_flops(fn, params, batch)
        trips = fcount.scan_trip_info(fn, params, batch)
    else:
        flops_global = fcount.count_step_flops(fn, params, caches, tokens,
                                               cache_len)
        trips = fcount.scan_trip_info(fn, params, caches, tokens, cache_len)
    flops = flops_global / n_chips
    rec["cost"] = {"flops_per_device": flops,
                   "flops_per_device_xla_loops_once": flops_xla,
                   "flops_global_jaxpr": flops_global,
                   "bytes_per_device": bytes_acc,
                   "scan_lengths": trips["scan_lengths"]}
    print(f"[cost/device] flops={flops:.3e} (xla-once {flops_xla:.3e}) "
          f"bytes={bytes_acc:.3e}")

    # ---- collectives from optimized HLO (per-depth trip correction) ----
    coll = roof.collective_bytes(compiled.as_text(),
                                 trips_by_depth=trips["by_depth"])
    rec["collectives"] = coll

    # ---- memory traffic: XLA bytes (loops-once) vs resident-buffer bound --
    resident = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    bytes_est = max(bytes_acc, float(resident))
    rec["cost"]["bytes_per_device_est"] = bytes_est

    # ---- analytic steady-state HBM traffic (decode rows): the resident
    # bound cannot credit PARTIAL reads (fp8 reads only `upper` weight
    # bytes; planar NestedKV reads only hi cache planes), so decode rows
    # use an analytic term = weights(mode) + cache(mode,format) + writes.
    if shape.kind == "decode":
        def _leaf_bytes(leaf):
            return float(leaf.size) * leaf.dtype.itemsize

        w_read = 0.0
        for _, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            b = _leaf_bytes(leaf)
            if leaf.dtype == jnp.uint8 and mode == "fp8":
                b *= 0.5     # NestedFP pairs: fp8 reads the upper byte only
            w_read += b
        c_read = c_write = 0.0
        for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
            keys = "/".join(str(getattr(k, "key", "")) for k in path)
            b = _leaf_bytes(leaf)
            if not (mode == "fp8" and "_lo" in keys):   # lo planes unread
                c_read += b
            cap_dim = leaf.shape[2] if leaf.ndim >= 3 else 1
            c_write += b / max(cap_dim, 1)              # one-token write
        analytic = (w_read + c_read + c_write) / n_chips
        rec["analytic_traffic"] = {
            "weights_read_gib": w_read / n_chips / 2**30,
            "cache_read_gib": c_read / n_chips / 2**30,
            "cache_write_gib": c_write / n_chips / 2**30,
            "memory_s_analytic": analytic / roof.HBM_BW,
        }
        print(f"[analytic] {json.dumps(rec['analytic_traffic'])}")
        bytes_est = analytic

    # ---- roofline terms ----
    terms = roof.roofline_terms(flops, bytes_est,
                                coll["weighted_wire_bytes"],
                                fp8=(mode == "fp8"))
    # count on the training tree: serving trees hold upper+lower byte pairs
    # for each weight and would double-count
    pcount = roof.count_params(
        steps.param_structs(cfg, serving=False),
        active_expert_fraction=(
            None if cfg.moe is None else
            (cfg.moe.top_k + cfg.moe.n_shared_experts) / cfg.moe.n_experts))
    mf = roof.model_flops(cfg, shape, pcount["active"])
    terms["model_flops_total"] = mf
    terms["useful_ratio"] = mf / max(flops * n_chips, 1.0)
    rec["roofline"] = terms
    rec["params"] = pcount
    rec["status"] = "ok"
    print(f"[roofline] {json.dumps(terms)}")
    return rec


def _combo_path(out_dir, arch, shape, mesh_kind, mode, kv="f16"):
    suffix = "" if kv == "f16" else f"__{kv}"
    return os.path.join(out_dir,
                        f"{arch}__{shape}__{mesh_kind}__{mode}{suffix}.json")


def orchestrate(args) -> int:
    from repro.configs import ASSIGNED, INPUT_SHAPES  # light import

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                path = _combo_path(out_dir, arch, shape, mk, args.mode)
                if os.path.exists(path) and not args.force:
                    print(f"skip existing {os.path.basename(path)}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mk,
                       "--mode", args.mode, "--out", out_dir]
                print(f"--- {arch} {shape} {mk} {args.mode}", flush=True)
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, mk))
                    print(f"FAILED: {arch} {shape} {mk}")
    if failures:
        print("failures:", failures)
        return 1
    print("all combos done")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="fp16", choices=["fp16", "fp8"])
    ap.add_argument("--kv", default="f16", choices=["f16", "planar"])
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all or args.arch == "all" or args.shape == "all" \
            or args.mesh == "both":
        return orchestrate(args)

    os.makedirs(args.out, exist_ok=True)
    path = _combo_path(args.out, args.arch, args.shape, args.mesh, args.mode,
                       args.kv)
    try:
        rec = run_one(args.arch, args.shape, args.mesh, args.mode, args.out,
                      kv=args.kv)
    except Exception as e:  # record the failure for the report
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "mode": args.mode, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(rec["error"])
        return 1
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
