"""Serving launcher: dual-precision engine over a trained/initialized model.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 16 --rate 4 [--policy dual|fp16|fp8]
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--system-prompt-len", type=int, default=0,
                    help="shared prefix tokens prepended to every request "
                         "(exercises COW prefix caching)")
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--speculate", action="store_true",
                    help="n-gram speculative decoding (greedy outputs are "
                         "bit-exact vs off; summary gains spec_* fields)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default="dual",
                    choices=["dual", "fp16", "fp8"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.core.policy import DualPrecisionController, SLOConfig
    from repro.models import model as M
    from repro.models.convert import serving_memory_bytes, to_serving
    from repro.serving.engine import Engine, Request

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        from repro.checkpoint import io
        restored, _ = io.restore(args.ckpt, {"params": params})
        params = restored["params"]
    sparams = to_serving(params)
    mem = serving_memory_bytes(sparams)
    print(f"serving params: {mem['total_bytes']/2**20:.1f} MiB "
          f"({mem['nested_bytes']/max(mem['total_bytes'],1)*100:.0f}% nested)")

    controller = None
    forced = None
    if args.policy == "dual":
        controller = DualPrecisionController(
            SLOConfig(), fp16_ms_per_token=0.5, fp8_ms_per_token=0.25)
    else:
        forced = args.policy

    eng = Engine(cfg, sparams, n_slots=args.slots, capacity=args.capacity,
                 controller=controller, forced_mode=forced,
                 prefix_cache=not args.no_prefix_cache,
                 speculate=args.speculate or None)
    rng = np.random.RandomState(args.seed)
    sys_prompt = list(rng.randint(1, cfg.vocab_size,
                                  args.system_prompt_len))
    for i in range(args.requests):
        plen = max(4, int(rng.normal(args.prompt_len, 4)))
        eng.submit(Request(f"r{i}",
                           sys_prompt + list(rng.randint(1, cfg.vocab_size,
                                                         plen)),
                           max_new=args.max_new))
    fin = eng.run()
    n_tokens = sum(len(r.output) for r in fin)
    modes = [m for r in fin for m in r.modes]
    ps = eng.prefix_cache_stats()
    print(json.dumps({
        "finished": len(fin), "tokens": n_tokens,
        "iterations": eng.iteration,
        "fp16_fraction": modes.count("fp16") / max(len(modes), 1),
        "prefix_hit_rate": round(ps["hit_rate"], 3),
        "blocks_saved": ps["blocks_saved"],
        "window_reclaimed_blocks": eng.stats["window_reclaimed_blocks"],
        **({"spec_acceptance_rate":
                round(eng.spec_stats()["acceptance_rate"], 3),
            "spec_tokens_per_dispatch":
                round(eng.spec_stats()["tokens_accepted_per_dispatch"], 3)}
           if args.speculate else {}),
    }))
    return 0 if len(fin) == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
