"""Logical-axis sharding resolver (MaxText-style rules, DESIGN.md §5).

Maps every param/batch/cache leaf to a PartitionSpec by inspecting its
path + shape. Rules degrade gracefully: any dimension not divisible by
the mesh axis falls back to replication (e.g. gemma3's 4 q-heads,
granite's 40 experts), with the documented alternate axis used where one
exists (expert-MoE -> per-expert d_ff).

All resolvers operate on ShapeDtypeStruct trees (from jax.eval_shape), so
the dry-run never allocates.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import data_axes, model_axis_size


def _div(n: int, m: int) -> bool:
    return n > 0 and m > 0 and n % m == 0


def _keys(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return out


# which head count governs a projection's sharded output dim
_Q_NAMES = ("wq", "wq_b")
_KV_NAMES = ("wk", "wv", "wk_b", "wv_b")
_OUT_NAMES = ("wo",)


def param_spec(path, leaf, cfg: ArchConfig, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    m = model_axis_size(mesh)
    d_axes = data_axes(mesh)
    dsz = 1
    for a in d_axes:
        dsz *= mesh.shape[a]
    keys = _keys(path)
    shape = leaf.shape
    stacked = any(k in ("layers", "enc_layers") for k in keys) \
        and len(shape) >= 1
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    rank = len(body)

    def spec(*dims):
        return P(*(lead + dims))

    # ---- embeddings / head (kept un-nested; vocab-sharded iff divisible)
    if "embed" in keys:                      # (V, D)
        return spec("model" if _div(body[0], m) else None, None)
    if "lm_head" in keys:                    # (D, V)
        if rank == 1:
            return spec("model" if _div(body[0], m) else None)
        return spec(None, "model" if _div(body[1], m) else None)
    if "router" in keys or "frontend_proj" in keys:
        return spec(*([None] * rank))

    # ---- MoE expert banks (E_pad, D, F) / (E_pad, F, D) — E_pad is chosen
    # divisible by the model axis (configs pad, e.g. granite 40 -> 48).
    # Expert-parallel axis cascade: widest divisible combination wins
    # (multi-pod: 256 experts shard over (data, model)=256 and replicate
    # over pod — 512-way EP does not divide).
    if any(k in keys for k in ("w_gate", "w_up", "w_down")):
        e = body[0]
        for axes in (d_axes + ("model",), ("data", "model"), ("model",)):
            if not all(a in mesh.axis_names for a in axes):
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if _div(e, size):
                ep_axes = axes if len(axes) > 1 else axes[0]
                return spec(ep_axes, None, None)
        down = "w_down" in keys
        f_dim = 1 if down else 2
        if _div(body[f_dim], m):             # per-expert tensor parallel
            return spec(None, "model", None) if down \
                else spec(None, None, "model")
        return spec(None, None, None)

    # ---- attention projections. Head-parallel (column) sharding when the
    # head count divides the model axis; otherwise ROW-parallel: shard the
    # d_model contraction dim so the weights still spread across devices
    # (deepseek-coder's 56 heads / granite's 24 heads would otherwise
    # replicate 12.7B attention params = 25 GiB/device). Row-parallel
    # attention computes QKV partial sums (one extra all-reduce) and runs
    # the attention math replicated over `model` — a memory-for-compute
    # trade recorded in EXPERIMENTS.md.
    n_heads = cfg.n_heads
    n_kv = cfg.n_kv_heads
    if any(k in keys for k in _Q_NAMES):
        if rank == 1:
            return spec("model" if _div(n_heads, m) else None)
        if _div(n_heads, m):
            return spec(None, "model")
        return spec("model" if _div(body[0], m) else None, None)
    if any(k in keys for k in _KV_NAMES):
        heads = n_heads if cfg.mla is not None else n_kv
        if rank == 1:
            return spec("model" if _div(heads, m) else None)
        if _div(heads, m):
            return spec(None, "model")
        if _div(n_heads, m):
            # kv_heads indivisible but q heads divide (gemma3's 4q/1kv):
            # keep q/out head-parallel and REPLICATE the small K/V
            # projections — every shard computes the full (few-head) K/V,
            # which the head-sharded attention then reads without any
            # collective. Row-parallelizing K/V here would force a
            # partial-sum all-reduce per layer to rebuild values that are
            # n_kv/n_heads the size of the q projection.
            return spec(None, None)
        return spec("model" if _div(body[0], m) else None, None)
    if any(k in keys for k in _OUT_NAMES):
        if rank == 1:
            return spec(None)
        if _div(n_heads, m):
            return spec("model", None)
        return spec(None, "model" if _div(body[1], m) else None)
    if "wq_a" in keys:                       # (D, q_lora_rank)
        ok = cfg.mla is not None and _div(cfg.mla.q_lora_rank, m)
        if rank == 1:
            return spec("model" if ok else None)
        return spec(None, "model" if ok else None)
    if "wkv_a" in keys:                      # tiny latent projection
        return spec(*([None] * rank))

    # ---- dense MLP
    if any(k in keys for k in ("gate", "up")):
        ff = body[-1] if rank >= 2 else body[0]
        ok = _div(ff, m)
        if rank == 1:
            return spec("model" if ok else None)
        return spec(None, "model" if ok else None)
    if "down" in keys:
        if rank == 1:
            return spec(None)
        return spec("model" if _div(body[0], m) else None, None)

    # ---- mamba2: z/x column-parallel over heads; bc/dt tiny, replicated
    if any(k in keys for k in ("in_z", "in_x")):
        ok = _div(body[-1], m)
        if rank == 1:
            return spec("model" if ok else None)
        return spec(None, "model" if ok else None)
    if any(k in keys for k in ("in_bc", "in_dt", "conv_wbc", "conv_bbc")):
        return spec(*([None] * rank))
    if "out_proj" in keys:
        if rank == 1:
            return spec(None)
        return spec("model" if _div(body[0], m) else None, None)
    if "conv_wx" in keys:                    # (W, d_inner)
        return spec(None, "model" if _div(body[-1], m) else None)
    if "conv_bx" in keys:
        return spec("model" if _div(body[0], m) else None)

    # ---- everything else (norms, scalars, dt/A/D, mtp proj): replicate
    return spec(*([None] * rank))


def opt_state_spec(path, leaf, cfg: ArchConfig, mesh) -> P:
    """ZeRO-1: AdamW moments take the param's spec PLUS `data` sharding on
    the first free divisible dim. Elementwise optimizer math then runs
    fully sharded; GSPMD all-gathers the updated params once per step
    (param-sized AG ≪ holding 2 f32 moments per param replicated over
    data — deepseek-coder-33b: 16.5 GiB/device -> ~1 GiB)."""
    base = param_spec(path, leaf, cfg, mesh)
    d_axes = data_axes(mesh)
    dsz = 1
    for a in d_axes:
        dsz *= mesh.shape[a]
    dims = list(base) + [None] * (len(leaf.shape) - len(base))
    taken = set()
    for d in dims:
        for a in (d if isinstance(d, tuple) else (d,)):
            if a:
                taken.add(a)
    if any(a in taken for a in d_axes):
        return base                       # expert banks already use data
    dt = d_axes if len(d_axes) > 1 else d_axes[0]
    for i, (d, size) in enumerate(zip(dims, leaf.shape)):
        if d is None and size % dsz == 0:
            dims[i] = dt
            return P(*dims)
    return base


def batch_spec(path, leaf, cfg: ArchConfig, mesh, *,
               micro: bool = False) -> P:
    """Batch leaves: tokens (B,S) / frames / patch_embeds; batch dim over
    the data axes when divisible. `micro` marks a leading n_micro axis."""
    d = data_axes(mesh)
    dsz = 1
    for a in d:
        dsz *= mesh.shape[a]
    shape = leaf.shape
    bdim = 1 if micro else 0
    if len(shape) <= bdim or not _div(shape[bdim], dsz):
        return P(*([None] * len(shape)))
    dims: list[Any] = [None] * len(shape)
    dims[bdim] = d if len(d) > 1 else d[0]
    return P(*dims)


def cache_spec(path, leaf, cfg: ArchConfig, mesh) -> P:
    """KV/state caches (leading layer dim). Batch over data when divisible,
    else (long_500k, B=1) the cache SEQUENCE axis goes over data —
    context parallelism; GSPMD inserts the partial-softmax collectives."""
    m = model_axis_size(mesh)
    d = data_axes(mesh)
    dsz = 1
    for a in d:
        dsz *= mesh.shape[a]
    daxes = d if len(d) > 1 else d[0]
    keys = _keys(path)
    shape = leaf.shape
    dims: list[Any] = [None] * len(shape)

    batch_ok = len(shape) >= 2 and _div(shape[1], dsz)
    if batch_ok:
        dims[1] = daxes

    if "ssm" in keys and len(shape) == 5:      # (L,B,H,P,N)
        if _div(shape[2], m):
            dims[2] = "model"
    elif "conv_x" in keys and len(shape) == 4:
        if _div(shape[3], m):                  # (L,B,W-1,d_inner)
            dims[3] = "model"
    elif "conv_bc" in keys and len(shape) == 4:
        pass                                   # tiny; replicate channels
    elif len(shape) == 5:                      # (L,B,Cap,hkv,hd) attn/cross
        if _div(shape[3], m):
            dims[3] = "model"
        elif _div(shape[2], m):
            dims[2] = "model"                  # kv-heads indivisible
        if not batch_ok and _div(shape[2], dsz):
            dt = daxes if isinstance(daxes, tuple) else (daxes,)
            dims[2] = (daxes if dims[2] is None
                       else dt + ("model",))   # context parallel
    elif len(shape) == 4:                      # (L,B,Cap,r) MLA latents —
        # no head axis: shard the SEQUENCE over model (context parallel;
        # GSPMD adds the partial-softmax psum). deepseek-v3 decode_32k
        # cache drops 18.4 GiB -> 1.15 GiB/device.
        if _div(shape[2], m):
            dims[2] = "model"
        if not batch_ok and _div(shape[2], dsz * m):
            dims[2] = daxes + ("model",)
        elif not batch_ok and _div(shape[2], dsz):
            dims[2] = daxes
    return P(*dims)


def paged_cache_spec(path, leaf, cfg: ArchConfig, mesh) -> P:
    """Serving-engine paged pools (model.init_paged_cache layout — no
    batch dim; sequences own block ids, not rows).

    * "attn"/"shared" GQA planes (L, NB, BS, Hkv, Hd) — f16 k/v or the
      four uint8 NestedKV byte planes — shard the KV-HEAD axis over
      `model` when divisible; indivisible head counts (gemma3's 1 kv
      head) replicate the pool, mirroring the K/V projection fallback in
      `param_spec` so pool and projection land on the same layout.
    * MLA latent planes (L, NB, BS, r): no head axis — replicate. The
      block axis CANNOT be sharded (the engine scatters at dynamic
      per-token physical indices) and latents are r≈576 f16/token small.
    * "ssm" slot planes: mamba2 state (L, slots, H, P, N) shards SSM
      heads, conv_x (L, slots, W-1, d_inner) shards channels — matching
      the column-parallel in_z/in_x weights; tiny conv_bc replicates.
    * block tables / everything else: replicate.
    """
    m = model_axis_size(mesh)
    keys = _keys(path)
    shape = leaf.shape
    dims: list[Any] = [None] * len(shape)
    if any(k in keys for k in ("attn", "shared")):
        if len(shape) == 5 and _div(shape[3], m):
            dims[3] = "model"
    elif "conv_x" in keys:
        if len(shape) == 4 and _div(shape[3], m):
            dims[3] = "model"
    elif "conv_bc" in keys:
        pass                                   # tiny; replicate channels
    elif "ssm" in keys:
        if len(shape) == 5 and _div(shape[2], m):
            dims[2] = "model"
    return P(*dims)


def tree_shardings(tree, mesh, rule, cfg: ArchConfig, **kw):
    """Map a ShapeDtypeStruct tree to NamedShardings via `rule`."""
    def per_leaf(path, leaf):
        return NamedSharding(mesh, rule(path, leaf, cfg, mesh, **kw))
    return jax.tree_util.tree_map_with_path(per_leaf, tree)


def scalar_sharding(mesh):
    return NamedSharding(mesh, P())
