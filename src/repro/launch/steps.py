"""Jit-able step functions + input_specs for every (arch × shape) pair.

  train_4k     -> train_step  (microbatched grad accumulation + AdamW)
  prefill_32k  -> prefill_step (NestedFP serving params)
  decode_32k   -> decode_step  (one token, full KV cache)
  long_500k    -> decode_step  (sub-quadratic archs only; DESIGN.md)

input_specs() returns ShapeDtypeStruct stand-ins for every input — weak-
type-correct, shardable, never allocated.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.data.pipeline import microbatch_split
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime
from repro.optim import adamw

TRAIN_RT = Runtime(mode="train", dtype=jnp.bfloat16)


def serve_rt(mode: str) -> Runtime:
    return Runtime(mode=mode, backend="ref", dtype=jnp.bfloat16,
                   fast_accum=True)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    """(params, opt_state, batch(n_micro, mb, ...)) -> (params, opt, metrics)."""

    def loss_fn(params, mb):
        return M.train_loss(TRAIN_RT, params, cfg, mb)

    def step(params, opt_state, batch):
        n_micro = jax.tree_util.tree_leaves(batch)[0].shape[0]

        def mb_body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                 acc_g, grads)
            return (acc_g, acc_l + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(mb_body, (zeros, jnp.float32(0.0)),
                                       batch)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": lsum / n_micro, **om}
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ArchConfig, mode: str = "fp16",
                      capacity: int | None = None):
    rt = serve_rt(mode)

    def step(params, batch):
        logits, caches, _ = M.prefill(rt, params, cfg, batch,
                                      capacity=capacity)
        return logits, caches

    return step


def make_decode_step(cfg: ArchConfig, mode: str = "fp16"):
    rt = serve_rt(mode)

    def step(params, caches, tokens, cache_len):
        return M.decode_step(rt, params, cfg, tokens, caches, cache_len)

    return step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# §Perf iteration M3 (REFUTED, kept for the record): running small archs'
# train step without microbatching removes the n_micro multiplier on
# per-layer collectives — but per-layer collective payloads are
# TOKEN-proportional, so a 16x bigger batch exactly cancels the 16x fewer
# trips (measured 54 s -> 53.6 s on granite, with 13x worse memory term).
# Only the param-proportional per-micro grad all-reduce shrinks. Empty set.
_SINGLE_SHOT_TRAIN: set[str] = set()


def micro_layout(shape: InputShape, data_size: int,
                 cfg: ArchConfig | None = None) -> tuple[int, int]:
    """(n_micro, micro_batch). Default: one sample per data shard per
    micro; small archs run the whole global batch in one shot."""
    if cfg is not None and cfg.arch_id in _SINGLE_SHOT_TRAIN:
        return 1, shape.global_batch
    mb = min(shape.global_batch, data_size)
    return shape.global_batch // mb, mb


def batch_specs(cfg: ArchConfig, shape: InputShape, *,
                data_size: int = 1) -> dict:
    """Model-input ShapeDtypeStructs for the given workload shape."""
    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        n_micro, mb = micro_layout(shape, data_size, cfg)
        out = {"tokens": _sds((n_micro, mb, s + 1), jnp.int32)}
        if cfg.frontend == "vision":
            out["patch_embeds"] = _sds(
                (n_micro, mb, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = _sds(
                (n_micro, mb, M.encdec_enc_len(s), cfg.frontend_dim),
                jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.frontend == "vision":
            out["patch_embeds"] = _sds((b, cfg.frontend_len,
                                        cfg.frontend_dim), jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = _sds((b, M.encdec_enc_len(s), cfg.frontend_dim),
                                 jnp.bfloat16)
        return out
    # decode
    return {"tokens": _sds((b, 1), jnp.int32),
            "cache_len": _sds((), jnp.int32)}


def param_structs(cfg: ArchConfig, *, serving: bool) -> Any:
    spec = jax.eval_shape(
        functools.partial(M.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if serving:
        spec = to_serving(spec, structural=True)
    return spec


def opt_structs(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, params_spec):
    return jax.eval_shape(
        functools.partial(adamw.init_state, opt_cfg), params_spec)


def cache_structs(cfg: ArchConfig, shape: InputShape,
                  planar: bool = False) -> Any:
    return jax.eval_shape(functools.partial(
        M.init_cache, cfg, shape.global_batch, shape.seq_len,
        planar=planar))


def shape_supported(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Shape policy (DESIGN.md): long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_sub_quadratic:
        return False, ("full-attention arch: 500k dense-cache serving is "
                       "quadratic at prefill; skipped per DESIGN.md shape "
                       "policy")
    return True, ""
