"""Checkpointing: flat-keyed npz shards + JSON index.

Pytrees are flattened to path-keyed arrays; large trees are split across
multiple .npz shards (size-capped) so restore can be partial/streamed.
Serving params round-trip NestedTensor/NestedLinearParams nodes via the
path encoding (no pickling).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


_SHARD_BYTES = 1 << 30     # 1 GiB per shard


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if leaf is None:
            continue
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def save(path: str, tree, *, step: int | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    shards: list[dict[str, np.ndarray]] = [{}]
    size = 0
    for k in sorted(flat):
        a = flat[k]
        if size + a.nbytes > _SHARD_BYTES and shards[-1]:
            shards.append({})
            size = 0
        shards[-1][k] = a
        size += a.nbytes
    index = {"step": step, "n_shards": len(shards),
             "keys": {k: i for i, sh in enumerate(shards) for k in sh}}
    for i, sh in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i}.npz"), **sh)
    with open(os.path.join(path, "index.json"), "w") as f:
        json.dump(index, f)


def restore(path: str, template) -> tuple[Any, int | None]:
    """Restore into `template`'s structure (shapes/dtypes validated)."""
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    loaded: dict[str, np.ndarray] = {}
    for i in range(index["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            loaded.update({k: z[k] for k in z.files})

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        key = jax.tree_util.keystr(path_keys)
        if leaf is None:
            leaves.append(None)
            continue
        if key not in loaded:
            raise KeyError(f"checkpoint missing {key}")
        arr = loaded[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), index.get("step")
