"""Pure-jnp oracles for every kernel in this package.

These are the ground truth used by the per-kernel allclose sweeps
(tests/test_kernels_*.py) and by NestedLinear when running on hosts where
Pallas is unavailable. All accumulate in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import nestedfp as nf


def matmul_f16_ref(x: jax.Array, w: jax.Array,
                   acc_dtype=jnp.float32) -> jax.Array:
    """Plain f16 GEMM oracle: (M,K) @ (K,N) -> (M,N).

    acc_dtype=bf16 is the serving fast-accum mode (Z4): partial sums cross
    shards in bf16, halving TP all-reduce bytes."""
    return jax.lax.dot_general(
        x.astype(jnp.float16), w.astype(jnp.float16),
        (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype)


def nestedfp16_matmul_ref(x: jax.Array, upper: jax.Array,
                          lower: jax.Array, acc_dtype=jnp.float32) -> jax.Array:
    """FP16-mode oracle: reconstruct the exact f16 weights, then GEMM."""
    w = nf.decode(upper, lower)
    return matmul_f16_ref(x, w, acc_dtype=acc_dtype)


def nestedfp8_matmul_ref(x_q: jax.Array, upper: jax.Array,
                         x_scale: jax.Array, acc_dtype=jnp.float32) -> jax.Array:
    """FP8-mode oracle.

    x_q:     (M,K) float8_e4m3fn quantized activations
    upper:   (K,N) uint8 NestedFP upper bytes (== e4m3 of w*2^8)
    x_scale: scalar (per-tensor) or (M,1) (per-token) dequant scale
    returns  (M,N) f32 == (x_q @ w_fp8) * x_scale * 2^-8
    """
    w8 = nf.fp8_view(upper)
    acc = jax.lax.dot_general(
        x_q.astype(acc_dtype), w8.astype(acc_dtype),
        (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype)
    return (acc * x_scale * nf.FP8_DEQUANT_SCALE).astype(acc_dtype)


def reconstruct_ref(upper: jax.Array, lower: jax.Array) -> jax.Array:
    """Oracle for the in-kernel bitwise reconstruction step alone."""
    return nf.decode(upper, lower)
