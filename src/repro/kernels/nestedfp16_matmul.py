"""Pallas TPU kernel: FP16 GEMM with on-the-fly NestedFP reconstruction.

TPU adaptation of the paper's CUTLASS RS kernel (§4.3):

  H100 (paper)                        TPU v5e (this kernel)
  ------------                        ---------------------
  TMA copies W1/W2 tiles to smem   -> BlockSpec HBM->VMEM tiles; Pallas'
                                      grid pipeline double-buffers the DMA
  SIMT byte ops in registers       -> VPU integer ops on the VMEM tile:
     (fused 4x8-bit, __byte_perm)      widen u8->u32, checksum subtract,
                                       shift/or, bitcast to f16 (lane-
                                       parallel, branch-free)
  WGMMA tensor-core pipeline       -> MXU via lax.dot_general on the
                                      reconstructed f16 tile, f32 accum
  3-stage pipeline + NVVM fence    -> Mosaic schedules VMEM ops; the DMA/
                                      compute overlap is the grid pipeline

The two 8-bit tensors are SEPARATE arrays (paper §4.1): FP8 mode DMAs only
`upper` (1 byte/weight); this FP16 kernel DMAs both (2 bytes/weight, same
traffic as a plain f16 GEMM — the paper's zero-amplification property).

Grid is (M/bm, N/bn, K/bk) with K innermost; a VMEM f32 scratch
accumulates partial products and is flushed to the output tile at the
last K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 256)  # (bm, bn, bk) — see EXPERIMENTS.md §Perf


def _reconstruct_f16(u: jax.Array, l: jax.Array) -> jax.Array:
    """Branch-free bitwise FP16 reconstruction (paper Fig. 6) on a tile."""
    u32 = u.astype(jnp.uint32)
    l32 = l.astype(jnp.uint32)
    sign = u32 >> 7
    corrected = (u32 & 0x7F) - (l32 >> 7)          # undo RNE carry
    bits = (sign << 15) | ((corrected >> 1) << 8) | l32
    return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.float16)


def _kernel(x_ref, u_ref, l_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _reconstruct_f16(u_ref[...], l_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def nestedfp16_matmul(x: jax.Array, upper: jax.Array, lower: jax.Array,
                      *, block: tuple[int, int, int] = DEFAULT_BLOCK,
                      out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """(M,K) f16 @ nested[(K,N) u8 x2] -> (M,N).

    Shapes must be multiples of `block` (ops.py pads arbitrary shapes).
    """
    m, k = x.shape
    k2, n = upper.shape
    assert k == k2 and upper.shape == lower.shape
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, upper.shape, block)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float16), upper, lower)
