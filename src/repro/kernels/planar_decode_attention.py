"""Pallas TPU kernel: single-token GQA decode attention over a byte-planar
(NestedKV) cache — the decode_32k hot path identified by the roofline
(EXPERIMENTS §3.3: cache reads are >95% of decode HBM traffic).

fp8 mode DMAs ONLY the hi planes (1 byte per cached element — half the
HBM traffic) and treats them as float8_e5m2 truncated values; fp16 mode
DMAs both planes and rejoins the exact f16 bits in VMEM. Online-softmax
accumulation across cache blocks (innermost grid dim), masked by per-row
valid lengths from SMEM.

Grid: (B, Hkv, Cap/block_c). Scratch: running (m, l, acc) per (b, head).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_C = 512


def _join(hi, lo):
    bits = (hi.astype(jnp.uint16) << 8) | lo.astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(bits, jnp.float16)


def _kernel_fp16(q_ref, khi_ref, klo_ref, vhi_ref, vlo_ref, lens_ref,
                 o_ref, m_ref, l_ref, acc_ref, *, n_blocks, block_c,
                 window=None, win_ref=None):
    _attend(q_ref,
            _join(khi_ref[0, 0], klo_ref[0, 0]),
            _join(vhi_ref[0, 0], vlo_ref[0, 0]),
            lens_ref, o_ref, m_ref, l_ref, acc_ref,
            n_blocks=n_blocks, block_c=block_c, window=window,
            win_ref=win_ref)


def _kernel_fp8(q_ref, khi_ref, vhi_ref, lens_ref,
                o_ref, m_ref, l_ref, acc_ref, *, n_blocks, block_c,
                window=None, win_ref=None):
    k = jax.lax.bitcast_convert_type(khi_ref[0, 0], jnp.float8_e5m2)
    v = jax.lax.bitcast_convert_type(vhi_ref[0, 0], jnp.float8_e5m2)
    _attend(q_ref, k.astype(jnp.float16), v.astype(jnp.float16),
            lens_ref, o_ref, m_ref, l_ref, acc_ref,
            n_blocks=n_blocks, block_c=block_c, window=window,
            win_ref=win_ref)


def _attend(q_ref, k, v, lens_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_blocks, block_c, window=None, win_ref=None):
    b = pl.program_id(0)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                  # (G, D)
    d = q.shape[-1]
    s = jax.lax.dot_general(                          # (G, block_c)
        q.astype(jnp.float32) * (d ** -0.5), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    kpos = ci * block_c + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, dimension=1)
    s = jnp.where(kpos < lens_ref[b], s, NEG_INF)
    if window is not None:
        # sliding-window (gemma3 local-layer) mask: the single query sits
        # at position len-1, so only keys with kpos > len-1-window attend
        # (same predicate as layers._apply_window)
        s = jnp.where(kpos > lens_ref[b] - 1 - window, s, NEG_INF)
    elif win_ref is not None:
        # traced window from SMEM (<= 0 means global): the same predicate
        # with the window read at run time, so one compiled kernel serves
        # every layer of a scanned local/global stack
        w = win_ref[0]
        s = jnp.where((w <= 0) | (kpos > lens_ref[b] - 1 - w), s, NEG_INF)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                            # (G, block_c)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ci == n_blocks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel_fp16(tables_ref, lens_ref, win_ref, q_ref, khi_ref,
                       klo_ref, vhi_ref, vlo_ref, o_ref, m_ref, l_ref,
                       acc_ref, *, n_blocks, block_c, window=None,
                       dyn_window=False):
    del tables_ref      # consumed by the index maps
    _kernel_fp16(q_ref, khi_ref, klo_ref, vhi_ref, vlo_ref, lens_ref,
                 o_ref, m_ref, l_ref, acc_ref,
                 n_blocks=n_blocks, block_c=block_c, window=window,
                 win_ref=win_ref if dyn_window else None)


def _paged_kernel_fp8(tables_ref, lens_ref, win_ref, q_ref, khi_ref,
                      vhi_ref, o_ref, m_ref, l_ref, acc_ref, *, n_blocks,
                      block_c, window=None, dyn_window=False):
    del tables_ref
    _kernel_fp8(q_ref, khi_ref, vhi_ref, lens_ref,
                o_ref, m_ref, l_ref, acc_ref,
                n_blocks=n_blocks, block_c=block_c, window=window,
                win_ref=win_ref if dyn_window else None)


@functools.partial(jax.jit, static_argnames=("fp8", "window", "interpret"))
def paged_planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, tables, lens, *,
                                  fp8: bool = False,
                                  window: int | None = None,
                                  window_arr=None,
                                  interpret: bool = False) -> jax.Array:
    """Block-paged variant: q: (B, H, D); planes: (NB, BS, Hkv, D) uint8
    physical pools (BS = KV block size, one grid step per block); tables:
    (B, MB) int32 per-sequence block tables in logical order (holes point
    at the trash block 0); lens: (B,) valid tokens per sequence.

    Returns (B, H, D) f32. The block table rides scalar prefetch
    (PrefetchScalarGridSpec) so each grid step's index_map DMAs the
    RIGHT physical block — the kernel body is the same online-softmax
    `_attend` as the dense-slot kernel, masking on logical positions.
    In fp8 mode only the hi planes are touched (half the HBM traffic).

    window (static): sliding-window size for gemma3-style LOCAL layers —
    keys at kpos <= len-1-window are masked exactly like the reference
    `_causal_window_mask`, so slide-freed table holes (pointing at the
    trash block) can never contribute. On real tables the engine only
    keeps the last ceil(window/BS)+1 blocks resident, so the masked-out
    grid steps DMA the one trash block instead of dead cache.

    window_arr (traced, (1,) int32, <= 0 means global): the same mask
    with the window read at run time — the engine's scanned decoder
    stack carries a per-layer window array, so the kernel must accept a
    traced value to compile ONCE for a mixed local/global stack. Applies
    only when `window` is None; the masks are arithmetic-identical, so
    window=w and window_arr=[w] produce bit-equal outputs."""
    bsz, h, d = q.shape
    bs_tok, hkv = k_hi.shape[1], k_hi.shape[2]
    mb = tables.shape[1]
    g = h // hkv
    qg = q.reshape(bsz, hkv, g, d)
    dyn_window = window is None and window_arr is not None
    if window_arr is None:       # placeholder keeps one prefetch layout
        window_arr = jnp.zeros((1,), jnp.int32)
    # pools laid out (NB, Hkv, BS, D) so one (block, head) tile is a
    # contiguous DMA per grid step
    planes = [p.transpose(0, 2, 1, 3) for p in (k_hi, k_lo, v_hi, v_lo)]

    q_spec = pl.BlockSpec((1, 1, g, d),
                          lambda b, hh, c, tab, ln, win: (b, hh, 0, 0))
    c_spec = pl.BlockSpec((1, 1, bs_tok, d),
                          lambda b, hh, c, tab, ln, win: (tab[b, c], hh, 0, 0))
    out_spec = pl.BlockSpec((1, 1, g, d),
                            lambda b, hh, c, tab, ln, win: (b, hh, 0, 0))
    out_shape = jax.ShapeDtypeStruct((bsz, hkv, g, d), jnp.float32)
    scratch = [pltpu.VMEM((g, 1), jnp.float32),
               pltpu.VMEM((g, 1), jnp.float32),
               pltpu.VMEM((g, d), jnp.float32)]

    if fp8:
        kernel = functools.partial(_paged_kernel_fp8, n_blocks=mb,
                                   block_c=bs_tok, window=window,
                                   dyn_window=dyn_window)
        ins = [planes[0], planes[2]]
        in_specs = [q_spec, c_spec, c_spec]
    else:
        kernel = functools.partial(_paged_kernel_fp16, n_blocks=mb,
                                   block_c=bs_tok, window=window,
                                   dyn_window=dyn_window)
        ins = planes
        in_specs = [q_spec, c_spec, c_spec, c_spec, c_spec]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, hkv, mb),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=scratch)
    out = pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                         interpret=interpret)(
        tables.astype(jnp.int32), lens.astype(jnp.int32),
        jnp.asarray(window_arr, jnp.int32).reshape(1), qg, *ins)
    return out.reshape(bsz, h, d)


@functools.partial(jax.jit,
                   static_argnames=("fp8", "block_c", "window", "interpret"))
def planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, lens, *,
                            fp8: bool = False,
                            block_c: int = DEFAULT_BLOCK_C,
                            window: int | None = None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, D) f16/f32; planes: (B, Cap, Hkv, D) uint8; lens: (B,).

    Returns (B, H, D) f32. Cap must divide block_c (ops-level padding).
    In fp8 mode only the hi planes are touched. `window` (static) masks
    keys outside the query's sliding window (gemma3 local layers)."""
    bsz, h, d = q.shape
    cap, hkv = k_hi.shape[1], k_hi.shape[2]
    g = h // hkv
    assert cap % block_c == 0, (cap, block_c)
    n_blocks = cap // block_c
    qg = q.reshape(bsz, hkv, g, d)
    # planes laid out (B, Hkv, Cap, D) so a (head, cache-block) tile is
    # contiguous per grid step
    planes = [p.transpose(0, 2, 1, 3) for p in (k_hi, k_lo, v_hi, v_lo)]

    q_spec = pl.BlockSpec((1, 1, g, d), lambda b, hh, c: (b, hh, 0, 0))
    c_spec = pl.BlockSpec((1, 1, block_c, d), lambda b, hh, c: (b, hh, c, 0))
    scratch = [pltpu.VMEM((g, 1), jnp.float32),
               pltpu.VMEM((g, 1), jnp.float32),
               pltpu.VMEM((g, d), jnp.float32)]
    out_spec = pl.BlockSpec((1, 1, g, d), lambda b, hh, c: (b, hh, 0, 0))
    out_shape = jax.ShapeDtypeStruct((bsz, hkv, g, d), jnp.float32)

    if fp8:
        out = pl.pallas_call(
            functools.partial(_kernel_fp8, n_blocks=n_blocks,
                              block_c=block_c, window=window),
            grid=(bsz, hkv, n_blocks),
            in_specs=[q_spec, c_spec, c_spec,
                      pl.BlockSpec(memory_space=pltpu.SMEM)],
            out_specs=out_spec, out_shape=out_shape,
            scratch_shapes=scratch, interpret=interpret,
        )(qg, planes[0], planes[2], lens.astype(jnp.int32))
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_fp16, n_blocks=n_blocks,
                              block_c=block_c, window=window),
            grid=(bsz, hkv, n_blocks),
            in_specs=[q_spec, c_spec, c_spec, c_spec, c_spec,
                      pl.BlockSpec(memory_space=pltpu.SMEM)],
            out_specs=out_spec, out_shape=out_shape,
            scratch_shapes=scratch, interpret=interpret,
        )(qg, *planes, lens.astype(jnp.int32))
    return out.reshape(bsz, h, d)
