"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * arbitrary-shape support (pad M/N/K up to block multiples, slice back)
  * backend dispatch:
      - "pallas":            real TPU lowering (Mosaic)
      - "pallas_interpret":  kernel body executed in Python on CPU — used
                             by the correctness sweeps
      - "ref":               pure-jnp oracle (ref.py). Default on CPU and
                             inside the 512-device dry-run, where a Mosaic
                             custom-call cannot lower. The ref path moves
                             the same bytes and issues the same matmul
                             FLOPs, so roofline terms are representative.
  * leading-batch flattening: inputs may be (..., K)

Set repro_backend() or pass backend=... explicitly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.f16_matmul import f16_matmul
from repro.kernels.nestedfp16_matmul import nestedfp16_matmul
from repro.kernels.nestedfp8_matmul import nestedfp8_matmul

_DEFAULT_BACKEND = None


def default_backend() -> str:
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        _DEFAULT_BACKEND = ("pallas" if jax.default_backend() == "tpu" else "ref")
    return _DEFAULT_BACKEND


def set_default_backend(name: str) -> None:
    global _DEFAULT_BACKEND
    assert name in ("pallas", "pallas_interpret", "ref")
    _DEFAULT_BACKEND = name


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _run_2d(x2d, call_padded, n_out, block):
    bm, bn, bk = block
    m = x2d.shape[0]
    xp = _pad_to(_pad_to(x2d, bm, 0), bk, 1)
    out = call_padded(xp)
    return out[:m, :n_out]


def matmul_nested_f16(x: jax.Array, upper: jax.Array, lower: jax.Array,
                      *, backend: str | None = None,
                      block=(128, 128, 256), out_dtype=jnp.float32,
                      acc_dtype=jnp.float32) -> jax.Array:
    """FP16-mode GEMM: x (..., K) @ nested[(K, N)] -> (..., N)."""
    backend = backend or default_backend()
    k, n = upper.shape
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    if backend == "ref":
        out = _ref.nestedfp16_matmul_ref(x2d, upper, lower, acc_dtype=acc_dtype)
    else:
        interp = backend == "pallas_interpret"
        up = _pad_to(_pad_to(upper, block[2], 0), block[1], 1)
        lp = _pad_to(_pad_to(lower, block[2], 0), block[1], 1)
        out = _run_2d(
            x2d,
            lambda xp: nestedfp16_matmul(xp, up, lp, block=block,
                                         out_dtype=jnp.float32, interpret=interp),
            n, block)
    return out.astype(out_dtype).reshape(*lead, n)


def matmul_nested_fp8(x_q: jax.Array, upper: jax.Array, x_scale: jax.Array,
                      *, backend: str | None = None,
                      block=(128, 128, 256), out_dtype=jnp.float32,
                      acc_dtype=jnp.float32) -> jax.Array:
    """FP8-mode GEMM: x_q (..., K) e4m3 @ upper (K, N) -> (..., N).

    x_scale: scalar per-tensor dequant scale, or (M, 1) per-token row
    scales (M = prod of x_q's leading dims). The pallas kernel takes a
    scalar only, so per-token scales dequant OUTSIDE the kernel — the
    scale is a linear factor on the accumulator, so the results are
    identical either way."""
    backend = backend or default_backend()
    k, n = upper.shape
    lead = x_q.shape[:-1]
    x2d = x_q.reshape(-1, k)
    per_token = getattr(x_scale, "ndim", 0) >= 2
    if backend == "ref":
        out = _ref.nestedfp8_matmul_ref(x2d, upper, x_scale, acc_dtype=acc_dtype)
    else:
        interp = backend == "pallas_interpret"
        up = _pad_to(_pad_to(upper, block[2], 0), block[1], 1)
        ks = jnp.float32(1.0) if per_token else x_scale
        out = _run_2d(
            x2d,
            lambda xp: nestedfp8_matmul(xp, up, jnp.atleast_1d(ks),
                                        block=block, out_dtype=jnp.float32,
                                        interpret=interp),
            n, block)
        if per_token:
            out = out * x_scale
    return out.astype(out_dtype).reshape(*lead, n)


def matmul_f16(x: jax.Array, w: jax.Array, *, backend: str | None = None,
               block=(128, 128, 256), out_dtype=jnp.float32,
               acc_dtype=jnp.float32) -> jax.Array:
    """Plain f16 GEMM (exception layers + overhead baseline)."""
    backend = backend or default_backend()
    k, n = w.shape
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    if backend == "ref":
        out = _ref.matmul_f16_ref(x2d, w, acc_dtype=acc_dtype)
    else:
        interp = backend == "pallas_interpret"
        wp = _pad_to(_pad_to(w, block[2], 0), block[1], 1)
        out = _run_2d(
            x2d,
            lambda xp: f16_matmul(xp, wp, block=block,
                                  out_dtype=jnp.float32, interpret=interp),
            n, block)
    return out.astype(out_dtype).reshape(*lead, n)
