"""Pallas TPU kernel: offline NestedFP encoding (paper Fig. 4a).

Converts an f16 weight tensor into the (upper, lower) byte pair in one
streaming pass — used when nesting multi-GB checkpoints on device, where
a fused kernel avoids materializing intermediate u32 tensors in HBM.
Pure VPU work: band-split, RNE rounding with carry, byte extraction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _kernel(w_ref, u_ref, l_ref):
    bits = jax.lax.bitcast_convert_type(w_ref[...], jnp.uint16).astype(jnp.uint32)
    sign = bits >> 15
    mag = bits & 0x7FFF
    keep = mag >> 7
    low = mag & 0x7F
    round_up = ((low > 0x40) | ((low == 0x40) & ((keep & 1) == 1))
                ).astype(jnp.uint32)
    keep = keep + round_up
    u_ref[...] = ((sign << 7) | (keep & 0x7F)).astype(jnp.uint8)
    l_ref[...] = (mag & 0xFF).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def nestedfp_encode(w: jax.Array, *, block: tuple[int, int] = DEFAULT_BLOCK,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(M, N) f16 -> ((M, N) uint8 upper, (M, N) uint8 lower).

    Caller guarantees applicability (|w| <= 1.75); shapes must be block
    multiples (ops-level padding as usual)."""
    m, n = w.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, (w.shape, block)
    grid = (m // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((m, n), jnp.uint8),
                   jax.ShapeDtypeStruct((m, n), jnp.uint8)),
        interpret=interpret,
    )(w.astype(jnp.float16))
