"""Pallas TPU kernel: plain f16 GEMM — the 'vanilla CUTLASS' baseline.

Identical grid/BlockSpec/accumulator structure to nestedfp16_matmul but
with a single pre-materialized f16 weight tensor and no reconstruction
step. The kernel-overhead benchmark (paper Fig. 7) compares the two; any
delta is exactly the cost of the in-kernel bitwise reconstruction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 256)


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def f16_matmul(x: jax.Array, w: jax.Array,
               *, block: tuple[int, int, int] = DEFAULT_BLOCK,
               out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float16), w.astype(jnp.float16))
