from repro.kernels import ops, ref
from repro.kernels.f16_matmul import f16_matmul
from repro.kernels.nestedfp16_matmul import nestedfp16_matmul
from repro.kernels.nestedfp8_matmul import nestedfp8_matmul, nestedfp8_matmul_fused_quant
from repro.kernels.nestedfp_encode import nestedfp_encode
from repro.kernels.planar_decode_attention import planar_decode_attention
from repro.kernels.flash_prefill_attention import flash_prefill_attention
