"""Pallas TPU kernel: causal GQA flash attention for prefill.

The model's default prefill path is a pure-JAX blockwise scan
(models/layers.attn_core_prefill) — correct and shardable, but each KV
block round-trips partial stats through XLA temporaries. This kernel
keeps the running (m, l, acc) in VMEM scratch across the innermost grid
dim and masks causally per tile, matching the standard TPU flash
schedule. Forward-only (prefill has no backward pass).

Grid: (B, Hkv, S/block_q, S/block_k); KV innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK = (256, 512)      # (block_q, block_k)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, bq, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    q = q_ref[0, 0].reshape(g * bq, d)
    k = k_ref[0, 0]                                   # (block_k, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q.astype(jnp.float32) * (d ** -0.5), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # causal tile mask: query row (g, qq) has global pos qi*bq + qq
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % bq
    qpos = qi * block_q + rows
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.reshape(g, bq, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def flash_prefill_attention(q, k, v, *, block=DEFAULT_BLOCK,
                            interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, Hkv, D). Returns (B, S, H, D) f32.

    S must divide both block sizes (ops-level padding as usual)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    bq, bk = block
    assert s % bq == 0 and s % bk == 0, (s, block)
    qg = q.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,S,D)
    kt = k.transpose(0, 2, 1, 3)                               # (B,Hkv,S,D)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, n_kv=s // bk, block_q=bq, block_k=bk),
        grid=(b, hkv, s // bq, s // bk),
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, d), lambda bb, hh, qi, ki: (bb, hh, 0, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, qi, ki: (bb, hh, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, qi, ki: (bb, hh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, d),
                               lambda bb, hh, qi, ki: (bb, hh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((g * bq, 1), jnp.float32),
                        pltpu.VMEM((g * bq, 1), jnp.float32),
                        pltpu.VMEM((g * bq, d), jnp.float32)],
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
