"""Pallas TPU kernel: FP8 GEMM on the NestedFP upper tensor.

This is the fast path of the paper (§4.1): only the `upper` byte of each
weight is DMA'd from HBM (1 byte/weight — half the FP16 traffic), and the
MXU runs at its 8-bit rate. The upper byte IS a valid float8_e4m3fn
encoding of w*2^8, so "dequantization" is a bitcast plus one scalar
multiply folded into the epilogue.

On real TPU (v6e+) the `dot_general` below hits the native fp8 MXU path;
on v5e the compiler upcasts tiles to bf16 in VMEM (weight HBM traffic —
the bandwidth term that matters at serving batch sizes — is still 1
byte/weight). Interpret mode (CPU tests) upcasts to f32.

A separate fused variant also quantizes the activation tile on the fly
(per-tensor scale passed in SMEM), saving one full activation round-trip
through HBM — a beyond-paper optimization recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.nestedfp import E4M3_MAX, FP8_DEQUANT_SCALE

DEFAULT_BLOCK = (128, 128, 256)


def _kernel(x_ref, u_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w8 = jax.lax.bitcast_convert_type(u_ref[...], jnp.float8_e4m3fn)
    # fp8 x fp8 -> f32: native MXU on v6e; interpret upcasts.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w8.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * scale_ref[0]
                      * FP8_DEQUANT_SCALE).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def nestedfp8_matmul(x_q: jax.Array, upper: jax.Array, x_scale: jax.Array,
                     *, block: tuple[int, int, int] = DEFAULT_BLOCK,
                     out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """(M,K) e4m3 @ upper[(K,N) u8] * (x_scale * 2^-8) -> (M,N).

    x_scale: per-tensor scalar dequant scale, shape (1,).
    """
    m, k = x_q.shape
    k2, n = upper.shape
    assert k == k2
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_q, upper, x_scale.reshape(1).astype(jnp.float32))


# -- fused activation-quant + GEMM (beyond-paper) -----------------------------

def _fused_kernel(x_ref, u_ref, amax_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    inv = E4M3_MAX / amax_ref[0]
    xq = jnp.clip(x_ref[...].astype(jnp.float32) * inv,
                  -E4M3_MAX, E4M3_MAX).astype(jnp.float8_e4m3fn)
    w8 = jax.lax.bitcast_convert_type(u_ref[...], jnp.float8_e4m3fn)
    acc_ref[...] += jax.lax.dot_general(
        xq.astype(jnp.float32), w8.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * (amax_ref[0] / E4M3_MAX)
                      * FP8_DEQUANT_SCALE).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def nestedfp8_matmul_fused_quant(x: jax.Array, upper: jax.Array,
                                 amax: jax.Array,
                                 *, block: tuple[int, int, int] = DEFAULT_BLOCK,
                                 out_dtype=jnp.float32,
                                 interpret: bool = False) -> jax.Array:
    """f16/bf16 activations in, quantized inside the kernel tile-by-tile.

    amax: precomputed per-tensor absmax of x, shape (1,). Saves the
    quantized-activation HBM round-trip of the unfused path.
    """
    m, k = x.shape
    _, n = upper.shape
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_fused_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, upper, amax.reshape(1).astype(jnp.float32))
