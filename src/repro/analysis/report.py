"""Human text + machine JSON rendering of a lint run."""

from __future__ import annotations

import collections
import json

from repro.analysis.rules import Finding


def summarize(findings: list[Finding]) -> dict:
    by_rule: dict[str, int] = collections.Counter()
    for f in findings:
        if f.active:
            by_rule[f.rule] += 1
    return {"total": len(findings),
            "active": sum(1 for f in findings if f.active),
            "suppressed": sum(1 for f in findings if f.suppressed),
            "baselined": sum(1 for f in findings if f.baselined),
            "active_by_rule": dict(sorted(by_rule.items()))}


def to_text(findings: list[Finding], *, verbose: bool = False) -> str:
    lines = []
    order = sorted(findings, key=lambda f: (f.path, f.line, f.rule))
    for f in order:
        if f.suppressed:
            if verbose:
                lines.append(f"{f.path}:{f.line}:{f.col}: {f.rule} "
                             f"[suppressed: {f.suppress_reason}] {f.message}")
            continue
        tag = " [baselined]" if f.baselined else ""
        lines.append(f"{f.path}:{f.line}:{f.col}: {f.rule}{tag} {f.message}")
    s = summarize(findings)
    lines.append(f"repro-lint: {s['active']} active finding(s) "
                 f"({s['suppressed']} suppressed, {s['baselined']} "
                 f"baselined, {s['total']} total)")
    if s["active_by_rule"]:
        lines.append("  active by rule: " + ", ".join(
            f"{r}={n}" for r, n in s["active_by_rule"].items()))
    return "\n".join(lines)


def to_json(findings: list[Finding]) -> str:
    return json.dumps({"summary": summarize(findings),
                       "findings": [f.to_dict() for f in sorted(
                           findings,
                           key=lambda f: (f.path, f.line, f.rule))]},
                      indent=2) + "\n"
