"""`repro-lint` entry point.

    repro-lint [paths...] [--baseline FILE] [--update-baseline]
               [--json FILE] [--root QUALNAME]... [--verbose]

Stdlib-only (`ast`) — runs without JAX installed, so the CI lint lane
needs no heavyweight environment. Exit status 1 iff any finding is
active (neither suppressed inline nor recorded in the baseline), or a
directive comment is malformed.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod, report
from repro.analysis.astutil import Module, load_module
from repro.analysis.callgraph import CallGraph
from repro.analysis.pallas_rules import PallasBlockSpecRule, TracedControlFlowRule
from repro.analysis.rules import DonationRule, Finding, HostSyncRule, JitCacheKeyRule

DEFAULT_SCAN = ("src/repro", "benchmarks", "examples")
# the analyzer audits the repo, not itself (its own strings/fixtures
# would otherwise trip the pattern matchers)
_SELF = "src/repro/analysis"


def _iter_files(paths: list[Path], repo_root: Path) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    out = []
    for f in files:
        try:
            rel = f.resolve().relative_to(repo_root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        if not rel.startswith(_SELF):
            out.append(f)
    return out


def _apply_suppressions(findings: list[Finding],
                        modules: dict[str, Module]) -> None:
    for f in findings:
        mod = modules.get(f.path)
        if mod is None:
            continue
        for d in mod.ignore_at(f.line):
            if f.rule in d.rules:
                f.suppressed = True
                f.suppress_reason = d.reason
                break


def _directive_findings(modules: dict[str, Module]) -> list[Finding]:
    out = []
    for mod in modules.values():
        for d in mod.directives:
            if not d.valid:
                out.append(Finding("NFP000", mod.rel, d.line, 0,
                                   f"malformed directive: {d.error}",
                                   "<module>"))
    return out


def run_analysis(paths: list[Path], repo_root: Path,
                 extra_roots: list[str] | None = None,
                 ) -> tuple[list[Finding], dict[str, Module]]:
    """Parse, build the call graph, run every rule, apply suppressions.
    Returns (findings, modules-by-relpath); baselining is the caller's
    second pass (the baseline file is optional)."""
    modules: dict[str, Module] = {}
    for f in _iter_files(paths, repo_root):
        try:
            mod = load_module(f, repo_root)
        except SyntaxError as e:
            raise SystemExit(f"repro-lint: cannot parse {f}: {e}")
        modules[mod.rel] = mod
    graph = CallGraph(list(modules.values()))
    findings: list[Finding] = []
    findings.extend(HostSyncRule(graph, extra_roots).run())
    findings.extend(DonationRule(graph).run())
    findings.extend(JitCacheKeyRule(graph).run())
    findings.extend(PallasBlockSpecRule(graph).run())
    findings.extend(TracedControlFlowRule(graph).run())
    findings.extend(_directive_findings(modules))
    _apply_suppressions(findings, modules)
    return findings, modules


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST lint for the NestedFP serving repo's hot-path "
                    "discipline (NFP001-NFP005)")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to scan (default: {', '.join(DEFAULT_SCAN)})")
    ap.add_argument("--repo-root", type=Path, default=Path.cwd())
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON; recorded findings do not fail the run")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file with the current "
                         "active findings and exit 0")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--root", action="append", default=[],
                    help="extra NFP001 hot root (qualname or suffix)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print suppressed findings")
    args = ap.parse_args(argv)

    root = args.repo_root
    paths = [Path(p) for p in args.paths] \
        or [root / p for p in DEFAULT_SCAN if (root / p).exists()]
    findings, _modules = run_analysis(paths, root, extra_roots=args.root)

    stale = 0
    if args.update_baseline:
        target = args.baseline or root / "nfp-baseline.json"
        baseline_mod.save(target, findings)
        print(f"repro-lint: baseline written to {target} "
              f"({sum(1 for f in findings if f.active)} finding(s))")
        return 0
    if args.baseline and args.baseline.exists():
        _matched, stale = baseline_mod.apply(args.baseline, findings)

    print(report.to_text(findings, verbose=args.verbose))
    if stale:
        print(f"repro-lint: warning: {stale} stale baseline entr"
              f"{'y' if stale == 1 else 'ies'} (fixed findings — prune "
              f"with --update-baseline)")
    if args.json:
        args.json.write_text(report.to_json(findings))
    return 1 if any(f.active for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
