"""NFP001–NFP003: hot-path host syncs, use-after-donation, jit keys.

All three rules are syntactic over-approximations tuned to THIS
codebase's discipline (engine.py's one-dispatch docstring): they cannot
prove a value lives on device, so they flag the patterns that are only
correct when it doesn't, and the `# nfp: ignore[...]` / baseline
mechanisms record the audited exceptions.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib

from repro.analysis.astutil import (Module, dotted_path, literal_int_tuple,
                                    resolve_call_target, unparse_short)
from repro.analysis.callgraph import CallGraph, FuncDef, FuncInfo


@dataclasses.dataclass
class Finding:
    rule: str
    path: str                  # repo-relative
    line: int
    col: int
    message: str
    symbol: str                # enclosing function qualname (or "<module>")
    suppressed: bool = False
    suppress_reason: str = ""
    baselined: bool = False

    @property
    def active(self) -> bool:
        return not (self.suppressed or self.baselined)

    def key(self) -> str:
        """Line-independent identity for the baseline file: a finding
        keeps its key when unrelated edits shift it up or down."""
        raw = f"{self.rule}|{self.path}|{self.symbol}|{self.message}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message, "key": self.key(),
                "suppressed": self.suppressed,
                "suppress_reason": self.suppress_reason,
                "baselined": self.baselined}


def _body_nodes(fn: FuncDef):
    """Walk a function body without descending into nested defs (they
    are separate call-graph nodes) — lambdas ARE descended (they belong
    to the enclosing function)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, FuncDef) or isinstance(node, ast.ClassDef):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _device_names(fn: FuncDef, mod: Module) -> set[str]:
    """Local names assigned from a jax/jnp call in this function —
    proxies for 'this value lives on device'."""
    out: set[str] = set()
    for node in _body_nodes(fn):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not isinstance(val, ast.Call):
            continue
        tgt = resolve_call_target(val, mod) or ""
        if tgt.startswith(("jax.", "jax.numpy.")):
            for t in node.targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                for e in elts:
                    if isinstance(e, ast.Name):
                        out.add(e.id)
    return out


# =============================================================================
# NFP001: host sync reachable from a hot root
# =============================================================================

_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_HOST_SAFE = (ast.Constant, ast.List, ast.Tuple, ast.Dict, ast.ListComp,
              ast.GeneratorExp, ast.DictComp, ast.SetComp)

DEFAULT_HOT_ROOTS = ["repro.serving.engine.Engine.step",
                     "repro.models.model.paged_step",
                     # mesh-mode dispatch wrapper and the device-table
                     # mirror: both sit on every sharded step, so flushes
                     # there are held to the same no-sync discipline
                     "repro.serving.shard.sharded_paged_step",
                     "repro.serving.kvcache.BlockManager.device_tables",
                     # speculative decoding rides inside the decode
                     # dispatch: the host-side draft proposer and the
                     # adaptive-K policy run every step and must stay
                     # pure bookkeeping (a sync there serializes decode)
                     "repro.serving.speculate.NgramProposer.propose",
                     "repro.core.policy.AdaptiveKController.decide",
                     # tiered-KV scheduling runs inside every step: spill
                     # capture is the ONE sanctioned aux d2h (inline
                     # nfp-ignore on its device_get), and the restore
                     # drain must stay scatter-dispatch + bookkeeping
                     "repro.serving.engine.Engine._flush_spills",
                     "repro.serving.engine.Engine._drain_restores",
                     # the multi-replica router steps EVERY replica from
                     # one host loop, and its failover drain runs while
                     # survivors still serve traffic: a sync in either
                     # stalls the whole fleet, not one engine
                     "repro.serving.router.Router.step",
                     "repro.serving.engine.Engine.drain_requests"]


def _host_safe_arg(arg: ast.AST, mod: Module) -> bool:
    """np.asarray on literals/comprehensions or numpy-produced values is
    host-side staging, not a device sync."""
    if isinstance(arg, _HOST_SAFE):
        return True
    if isinstance(arg, ast.Call):
        tgt = resolve_call_target(arg, mod) or ""
        return tgt.startswith("numpy.")
    return False


class HostSyncRule:
    """NFP001: the engine syncs device results exactly once per step, in
    the declared `# nfp: sync-point` function. Any other device->host
    pull reachable from a hot root is a stall XLA cannot hide."""
    rule = "NFP001"

    def __init__(self, graph: CallGraph, extra_roots: list[str] | None = None):
        self.graph = graph
        roots = list(DEFAULT_HOT_ROOTS) + list(extra_roots or [])
        for fi in graph.funcs.values():
            if fi.module.marker_for_def(fi.node, "hot-path"):
                roots.append(fi.qualname)
        self.sync_points = {fi.qualname for fi in graph.funcs.values()
                            if fi.module.marker_for_def(fi.node, "sync-point")}
        self.roots = graph.match_roots(roots)
        self.hot = graph.reachable(self.roots, stop=self.sync_points)

    def run(self) -> list[Finding]:
        findings: list[Finding] = []
        for qual in sorted(self.hot):
            fi = self.graph.funcs[qual]
            findings.extend(self._scan(fi))
        return findings

    def _project_call_in(self, node: ast.AST, fi: FuncInfo) -> bool:
        """Does the subtree call into project code (which, on a hot
        path, returns device values)?"""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and self.graph._resolve(sub, fi):
                return True
        return False

    def _scan(self, fi: FuncInfo) -> list[Finding]:
        mod = fi.module
        device = _device_names(fi.node, mod)
        out: list[Finding] = []

        def flag(node: ast.AST, what: str) -> None:
            out.append(Finding(self.rule, mod.rel, node.lineno,
                               node.col_offset,
                               f"host sync in hot path: {what}",
                               fi.qualname))

        for node in _body_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            tgt = resolve_call_target(node, mod) or ""
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_ATTRS:
                flag(node, f"`.{node.func.attr}()` forces a device->host "
                           f"transfer ({unparse_short(node)})")
            elif tgt in ("numpy.asarray", "numpy.array"):
                if node.args and not _host_safe_arg(node.args[0], mod):
                    flag(node, f"`{unparse_short(node)}` pulls a (possibly "
                               f"device) value to host")
            elif tgt == "jax.device_get":
                flag(node, f"`{unparse_short(node)}`")
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("int", "float", "bool") and node.args:
                names = {n.id for n in ast.walk(node.args[0])
                         if isinstance(n, ast.Name)}
                if names & device:
                    flag(node, f"`{unparse_short(node)}` scalarizes a "
                               f"device value")
                elif self._project_call_in(node.args[0], fi):
                    flag(node, f"`{unparse_short(node)}` scalarizes a "
                               f"project-call result (device value on "
                               f"this path)")
        return out


# =============================================================================
# NFP002: use after donation
# =============================================================================

def _donate_positions(call: ast.Call) -> tuple[int, ...] | None:
    """donate_argnums of a jax.jit(...) call, else None."""
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return literal_int_tuple(kw.value)
    return None


def _is_jit_call(call: ast.Call, mod: Module) -> bool:
    return (resolve_call_target(call, mod) or "") in (
        "jax.jit", "jax.pjit", "jax.jit.jit")


class _DonationRegistry:
    """Where do donated callables live in this module?

    * bindings:   dotted path / bare name called directly
                  (`self._zero_slot(...)`, `_table_scatter(...)`)
    * containers: dict/cache paths indexed at the call site
                  (`self._decode[mode](...)`)
    * factories:  functions whose return value is a donated callable
                  (`self._chunk_fn(mode, b)(...)`)
    """

    def __init__(self, mod: Module):
        self.bindings: dict[str, tuple[int, ...]] = {}
        self.containers: dict[str, tuple[int, ...]] = {}
        self.factories: dict[str, tuple[int, ...]] = {}
        self._collect(mod)

    def _jit_donate(self, node: ast.AST, mod: Module) -> tuple[int, ...] | None:
        if isinstance(node, ast.Call) and _is_jit_call(node, mod):
            return _donate_positions(node)
        return None

    def _collect(self, mod: Module) -> None:
        # pass 1: direct jit(...) bindings, decorated defs, factories
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                self._collect_assign(node, mod, factories=False)
            elif isinstance(node, FuncDef):
                pos = self._decorated_positions(node, mod)
                if pos:
                    self.bindings[node.name] = pos
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Return) and sub.value is not None:
                        pos = self._jit_donate(sub.value, mod)
                        if pos:
                            self.factories[node.name] = pos
        # pass 2: bindings built FROM factories/containers
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                self._collect_assign(node, mod, factories=True)
            elif isinstance(node, FuncDef):
                # `return self._cache[key]` where the container is donated
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Return) \
                            and isinstance(sub.value, ast.Subscript):
                        path = dotted_path(sub.value.value)
                        if path in self.containers:
                            self.factories.setdefault(
                                node.name, self.containers[path])

    def _decorated_positions(self, node: FuncDef,
                             mod: Module) -> tuple[int, ...] | None:
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            tgt = resolve_call_target(dec, mod) or ""
            if tgt.endswith("partial") and dec.args:
                inner = dec.args[0]
                if (dotted_path(inner) or "").endswith("jit") \
                        or (isinstance(inner, ast.Attribute)
                            and inner.attr == "jit"):
                    pos = None
                    for kw in dec.keywords:
                        if kw.arg == "donate_argnums":
                            pos = literal_int_tuple(kw.value)
                    if pos:
                        return pos
            elif tgt in ("jax.jit", "jax.pjit"):
                pos = _donate_positions(dec)
                if pos:
                    return pos
        return None

    def _value_positions(self, val: ast.AST, mod: Module,
                         factories: bool) -> tuple[int, ...] | None:
        pos = self._jit_donate(val, mod)
        if pos:
            return pos
        if factories and isinstance(val, ast.Call):
            name = val.func.id if isinstance(val.func, ast.Name) else \
                val.func.attr if isinstance(val.func, ast.Attribute) else None
            if name in self.factories:
                return self.factories[name]
        return None

    def _collect_assign(self, node: ast.Assign, mod: Module,
                        factories: bool) -> None:
        val = node.value
        # dict literal / comprehension of donated callables
        inner = None
        if isinstance(val, ast.DictComp):
            inner = val.value
        elif isinstance(val, ast.Dict) and val.values:
            inner = val.values[0]
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                path = dotted_path(tgt.value)
                pos = self._value_positions(val, mod, factories)
                if path and pos:
                    self.containers.setdefault(path, pos)
            else:
                path = dotted_path(tgt)
                if not path:
                    continue
                pos = self._value_positions(val, mod, factories)
                if pos:
                    self.bindings.setdefault(path, pos)
                elif inner is not None:
                    ipos = self._value_positions(inner, mod, factories)
                    if ipos:
                        self.containers.setdefault(path, ipos)

    def positions_for_call(self, call: ast.Call) -> tuple[int, ...] | None:
        f = call.func
        path = dotted_path(f)
        if path:
            if path in self.bindings:
                return self.bindings[path]
            bare = path.split(".")[-1]
            if path.startswith("self.") and bare in self.bindings:
                return self.bindings[bare]
        if isinstance(f, ast.Subscript):
            cpath = dotted_path(f.value)
            if cpath in self.containers:
                return self.containers[cpath]
        if isinstance(f, ast.Call):
            name = f.func.id if isinstance(f.func, ast.Name) else \
                f.func.attr if isinstance(f.func, ast.Attribute) else None
            if name in self.factories:
                return self.factories[name]
        return None


class DonationRule:
    """NFP002: a buffer passed at a donate_argnums position is dead the
    moment the call is issued — XLA may already have reused its pages.
    Any read before the name is rebound is a use-after-free (JAX raises
    at runtime on CPU, but only when the buffer is actually donated —
    interpret/backend changes can hide it)."""
    rule = "NFP002"

    def __init__(self, graph: CallGraph):
        self.graph = graph
        self._registries: dict[int, _DonationRegistry] = {}

    def run(self) -> list[Finding]:
        findings: list[Finding] = []
        for qual in sorted(self.graph.funcs):
            fi = self.graph.funcs[qual]
            reg = self._registries.get(id(fi.module))
            if reg is None:
                reg = self._registries[id(fi.module)] = \
                    _DonationRegistry(fi.module)
            findings.extend(self._scan(fi, reg))
        return findings

    def _scan(self, fi: FuncInfo, reg: _DonationRegistry) -> list[Finding]:
        found: dict[tuple[int, str], Finding] = {}

        def report(node: ast.AST, path: str, donor_line: int) -> None:
            k = (node.lineno, path)
            if k not in found:
                found[k] = Finding(
                    self.rule, fi.module.rel, node.lineno, node.col_offset,
                    f"`{path}` used after being donated (donate_argnums "
                    f"call on line {donor_line}); rebind it from the "
                    f"call's result first", fi.qualname)

        def check_uses(expr: ast.AST, poison: dict[str, int]) -> None:
            for node in ast.walk(expr):
                if isinstance(node, (ast.Name, ast.Attribute)) \
                        and isinstance(getattr(node, "ctx", None), ast.Load):
                    p = dotted_path(node)
                    if p in poison:
                        report(node, p, poison[p])

        def apply_donations(expr: ast.AST, poison: dict[str, int]) -> None:
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                pos = reg.positions_for_call(node)
                if not pos:
                    continue
                for i in pos:
                    if i < len(node.args):
                        p = dotted_path(node.args[i])
                        if p:
                            poison[p] = node.lineno

        def exec_expr(expr: ast.AST | None, poison: dict[str, int]) -> None:
            if expr is None:
                return
            check_uses(expr, poison)
            apply_donations(expr, poison)

        def clear_target(tgt: ast.AST, poison: dict[str, int]) -> None:
            elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                else [tgt]
            for e in elts:
                if isinstance(e, ast.Starred):
                    e = e.value
                p = dotted_path(e)
                if p:
                    poison.pop(p, None)

        def exec_block(stmts, poison: dict[str, int]) -> None:
            for st in stmts:
                exec_stmt(st, poison)

        def merge(a: dict[str, int], b: dict[str, int]) -> dict[str, int]:
            out = dict(a)
            out.update(b)
            return out

        def exec_stmt(st: ast.stmt, poison: dict[str, int]) -> None:
            if isinstance(st, (FuncDef, ast.ClassDef)):
                return
            if isinstance(st, (ast.Assign, ast.AnnAssign)):
                exec_expr(st.value, poison)
                tgts = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for t in tgts:
                    clear_target(t, poison)
            elif isinstance(st, ast.AugAssign):
                exec_expr(st.value, poison)
                p = dotted_path(st.target)
                if p in poison:
                    report(st.target, p, poison[p])
                clear_target(st.target, poison)
            elif isinstance(st, ast.If):
                exec_expr(st.test, poison)
                b1, b2 = dict(poison), dict(poison)
                exec_block(st.body, b1)
                exec_block(st.orelse, b2)
                poison.clear()
                poison.update(merge(b1, b2))
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                exec_expr(st.iter, poison)
                clear_target(st.target, poison)
                # two passes over the body: the second catches a use in
                # iteration N of a name donated in iteration N-1
                exec_block(st.body, poison)
                clear_target(st.target, poison)
                exec_block(st.body, poison)
                exec_block(st.orelse, poison)
            elif isinstance(st, ast.While):
                exec_expr(st.test, poison)
                exec_block(st.body, poison)
                exec_expr(st.test, poison)
                exec_block(st.body, poison)
                exec_block(st.orelse, poison)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    exec_expr(item.context_expr, poison)
                    if item.optional_vars is not None:
                        clear_target(item.optional_vars, poison)
                exec_block(st.body, poison)
            elif isinstance(st, ast.Try):
                exec_block(st.body, poison)
                for h in st.handlers:
                    exec_block(h.body, poison)
                exec_block(st.orelse, poison)
                exec_block(st.finalbody, poison)
            elif isinstance(st, ast.Delete):
                for t in st.targets:
                    clear_target(t, poison)
            else:
                for val in ast.iter_child_nodes(st):
                    if isinstance(val, ast.expr):
                        exec_expr(val, poison)

        exec_block(fi.node.body, {})
        return [found[k] for k in sorted(found)]


# =============================================================================
# NFP003: unbounded jit-cache key
# =============================================================================

_BUCKET_HELPERS = ("bucket", "pow2", "cdiv")


def _is_bucket_call(call: ast.Call) -> bool:
    name = call.func.id if isinstance(call.func, ast.Name) else \
        call.func.attr if isinstance(call.func, ast.Attribute) else ""
    return any(h in name.lower() for h in _BUCKET_HELPERS)


class JitCacheKeyRule:
    """NFP003: functions that memoize `jax.jit` executables by key must
    be fed keys of bounded cardinality — a raw length/count key compiles
    one executable per distinct value (recompile storm + unbounded
    device memory). Keys must come from a pow2/bucket helper or be
    constants."""
    rule = "NFP003"

    def __init__(self, graph: CallGraph):
        self.graph = graph
        # cache-fn qualname -> ordered param names that feed the key
        self.cache_fns: dict[str, list[str]] = {}
        for qual, fi in graph.funcs.items():
            params = self._key_params(fi)
            if params:
                self.cache_fns[qual] = params

    def _key_params(self, fi: FuncInfo) -> list[str] | None:
        """Does this function do `container[key] = jax.jit(...)` with
        `key` built from its own parameters? Returns those parameters."""
        pnames = [a.arg for a in fi.node.args.args if a.arg != "self"]
        if not pnames:
            return None
        key_exprs: dict[str, ast.AST] = {}
        for node in _body_nodes(fi.node):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.targets[0], ast.Name):
                key_exprs[node.targets[0].id] = node.value
        for node in _body_nodes(fi.node):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.targets[0], ast.Subscript)
                    and isinstance(node.value, ast.Call)
                    and _is_jit_call(node.value, fi.module)):
                continue
            key = node.targets[0].slice
            if isinstance(key, ast.Name) and key.id in key_exprs:
                key = key_exprs[key.id]
            elts = key.elts if isinstance(key, ast.Tuple) else [key]
            used = [e.id for e in elts
                    if isinstance(e, ast.Name) and e.id in pnames]
            if used:
                return pnames
        return None

    def run(self) -> list[Finding]:
        findings: list[Finding] = []
        for qual in sorted(self.graph.funcs):
            caller = self.graph.funcs[qual]
            for node in _body_nodes(caller.node):
                if isinstance(node, ast.Call):
                    findings.extend(self._check_call(node, caller))
        return findings

    def _target_cache_fn(self, call: ast.Call,
                         caller: FuncInfo) -> tuple[str, list[str]] | None:
        for target in self.graph._resolve(call, caller):
            if target in self.cache_fns:
                return target, self.cache_fns[target]
        return None

    def _check_call(self, call: ast.Call,
                    caller: FuncInfo) -> list[Finding]:
        hit = self._target_cache_fn(call, caller)
        if hit is None:
            return []
        target, params = hit
        assigns: dict[str, list[ast.AST]] = {}
        for node in _body_nodes(caller.node):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        assigns.setdefault(t.id, []).append(node.value)
        out = []
        args = list(call.args[: len(params)])
        for pname, arg in zip(params, args):
            if self._classify(arg, caller, assigns, depth=0) == "raw":
                out.append(Finding(
                    self.rule, caller.module.rel, arg.lineno, arg.col_offset,
                    f"jit cache `{target.split('.')[-1]}` keyed on raw "
                    f"value `{unparse_short(arg)}` (param `{pname}`) — "
                    f"derive it from a pow2/bucket helper or the cache "
                    f"grows per distinct value", caller.qualname))
        return out

    def _classify(self, expr: ast.AST, caller: FuncInfo,
                  assigns: dict[str, list[ast.AST]], depth: int) -> str:
        """'ok' (bounded), 'raw' (provably unbounded), 'unknown'."""
        if depth > 4:
            return "unknown"
        if isinstance(expr, ast.Constant):
            return "ok"
        if isinstance(expr, ast.Call):
            if _is_bucket_call(expr):
                return "ok"
            name = expr.func.id if isinstance(expr.func, ast.Name) else ""
            if name in ("len", "max", "min", "sum"):
                return "raw"
            return "unknown"
        if isinstance(expr, ast.BinOp):
            return "raw"
        if isinstance(expr, ast.Name):
            for a in caller.node.args.args:
                if a.arg == expr.id:
                    ann = a.annotation
                    if isinstance(ann, ast.Name) and ann.id == "int":
                        return "raw"
                    return "unknown"
            kinds = {self._classify(v, caller, assigns, depth + 1)
                     for v in assigns.get(expr.id, ())}
            if "raw" in kinds:
                return "raw"
            if kinds == {"ok"}:
                return "ok"
            return "unknown"
        return "unknown"
