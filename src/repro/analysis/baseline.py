"""Committed-baseline support: intentional findings live in a JSON file
(`nfp-baseline.json` at the repo root) keyed line-independently, so the
lint lane fails only on NEW findings while the recorded ones stay
visible in every report."""

from __future__ import annotations

import collections
import json
from pathlib import Path

from repro.analysis.rules import Finding

BASELINE_VERSION = 1


def save(path: Path, findings: list[Finding]) -> None:
    """Record every currently-active finding as intentional."""
    entries = [{"key": f.key(), "rule": f.rule, "path": f.path,
                "symbol": f.symbol, "message": f.message}
               for f in findings if f.active]
    entries.sort(key=lambda e: (e["path"], e["rule"], e["key"]))
    path.write_text(json.dumps(
        {"version": BASELINE_VERSION,
         "comment": "intentional repro-lint findings; regenerate with "
                    "`repro-lint --update-baseline`",
         "findings": entries}, indent=2) + "\n")


def apply(path: Path, findings: list[Finding]) -> tuple[int, int]:
    """Mark findings present in the baseline. Returns (matched, stale):
    stale entries match nothing anymore and should be pruned with
    `--update-baseline`."""
    data = json.loads(path.read_text())
    budget = collections.Counter(e["key"] for e in data.get("findings", ()))
    matched = 0
    for f in findings:
        if f.suppressed or not budget.get(f.key()):
            continue
        budget[f.key()] -= 1
        f.baselined = True
        matched += 1
    stale = sum(budget.values())
    return matched, stale
