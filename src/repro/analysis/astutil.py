"""Parsing layer: modules, import aliases, and `# nfp:` directives.

Everything downstream works on `Module` objects — a parsed tree plus
the module's dotted name (so hot roots like
``repro.serving.engine.Engine.step`` resolve), its import alias maps
(so ``np.asarray`` is recognized whatever numpy was imported as), and
its directive comments.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

RULE_IDS = ("NFP001", "NFP002", "NFP003", "NFP004", "NFP005")

# `# nfp: ignore[NFP001,NFP002] reason` | `# nfp: hot-path` | `# nfp: sync-point`
_DIRECTIVE_RE = re.compile(
    r"#\s*nfp:\s*(?:ignore\[(?P<rules>[^\]]*)\](?P<reason>.*)"
    r"|(?P<marker>hot-path|sync-point)\b.*)")


@dataclasses.dataclass
class Directive:
    line: int                  # 1-based line the comment sits on
    kind: str                  # "ignore" | "hot-path" | "sync-point"
    rules: tuple[str, ...]     # for "ignore": rule ids it suppresses
    reason: str
    standalone: bool           # comment-only line: applies to the NEXT line
    valid: bool = True
    error: str = ""


def parse_directives(lines: list[str]) -> list[Directive]:
    out = []
    for i, raw in enumerate(lines, start=1):
        m = _DIRECTIVE_RE.search(raw)
        if not m:
            if re.search(r"#\s*nfp:", raw):
                out.append(Directive(i, "ignore", (), "", False, valid=False,
                                     error="unrecognized `# nfp:` directive"))
            continue
        standalone = raw.lstrip().startswith("#")
        if m.group("marker"):
            out.append(Directive(i, m.group("marker"), (), "", standalone))
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = (m.group("reason") or "").strip()
        bad = [r for r in rules if r not in RULE_IDS]
        if bad:
            out.append(Directive(i, "ignore", rules, reason, standalone,
                                 valid=False,
                                 error=f"unknown rule id(s): {', '.join(bad)}"))
        elif not rules:
            out.append(Directive(i, "ignore", rules, reason, standalone,
                                 valid=False,
                                 error="ignore directive lists no rule ids"))
        elif not reason:
            out.append(Directive(i, "ignore", rules, reason, standalone,
                                 valid=False,
                                 error="ignore directive requires a reason"))
        else:
            out.append(Directive(i, "ignore", rules, reason, standalone))
    return out


@dataclasses.dataclass
class Module:
    path: Path
    rel: str                       # repo-relative posix path (reports)
    name: str                      # dotted module name, best effort
    tree: ast.Module
    lines: list[str]
    directives: list[Directive]
    mod_aliases: dict[str, str]    # "np" -> "numpy", "M" -> "repro.models.model"
    from_imports: dict[str, str]   # "paged_step" -> "repro.models.model.paged_step"

    def ignore_at(self, line: int) -> list[Directive]:
        """Ignore directives governing `line`: same-line trailing comment
        or a standalone directive on the line directly above."""
        hits = []
        for d in self.directives:
            if d.kind != "ignore" or not d.valid:
                continue
            if d.line == line or (d.standalone and d.line == line - 1):
                hits.append(d)
        return hits

    def marker_for_def(self, node: ast.AST, kind: str) -> bool:
        """Is a `hot-path`/`sync-point` marker attached to this def (on
        the def line, or standalone directly above the def/decorators)?"""
        first = min([node.lineno]
                    + [d.lineno for d in getattr(node, "decorator_list", [])])
        for d in self.directives:
            if d.kind != kind:
                continue
            if d.line == node.lineno or (d.standalone and d.line == first - 1):
                return True
        return False


def module_name_for(path: Path, repo_root: Path) -> str:
    try:
        rel = path.resolve().relative_to(repo_root.resolve())
    except ValueError:
        rel = Path(path.name)
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_imports(tree: ast.Module) -> tuple[dict[str, str], dict[str, str]]:
    mod_aliases: dict[str, str] = {}
    from_imports: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod_aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                full = f"{node.module}.{a.name}"
                local = a.asname or a.name
                from_imports[local] = full
                # `from jax.experimental import pallas as pl`: pl.* calls
                # resolve like a module alias
                mod_aliases.setdefault(local, full)
    return mod_aliases, from_imports


def load_module(path: Path, repo_root: Path) -> Module:
    src = path.read_text()
    tree = ast.parse(src, filename=str(path))
    lines = src.splitlines()
    mod_aliases, from_imports = _collect_imports(tree)
    try:
        rel = path.resolve().relative_to(repo_root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return Module(path=path, rel=rel,
                  name=module_name_for(path, repo_root), tree=tree,
                  lines=lines, directives=parse_directives(lines),
                  mod_aliases=mod_aliases, from_imports=from_imports)


# -- small AST helpers shared by the rules -----------------------------------

def dotted_path(node: ast.AST) -> str | None:
    """`self.caches` -> "self.caches", `a.b.c` -> "a.b.c", Name -> id;
    anything else (calls, subscripts) -> None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_call_target(node: ast.Call, mod: Module) -> str | None:
    """Best-effort fully-qualified name of a call's target: resolves
    module aliases (`np.asarray` -> "numpy.asarray", `M.paged_step` ->
    "repro.models.model.paged_step") and from-imports."""
    f = node.func
    if isinstance(f, ast.Name):
        return mod.from_imports.get(f.id, f.id)
    path = dotted_path(f)
    if path is None:
        return None
    head, _, rest = path.partition(".")
    if head in mod.mod_aliases and rest:
        return f"{mod.mod_aliases[head]}.{rest}"
    return path


def unparse_short(node: ast.AST, limit: int = 48) -> str:
    try:
        s = ast.unparse(node)
    except Exception:
        s = f"<{type(node).__name__}>"
    s = " ".join(s.split())
    return s if len(s) <= limit else s[: limit - 1] + "…"


def literal_int_tuple(node: ast.AST) -> tuple[int, ...] | None:
    """donate_argnums value: int or tuple of ints, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, ast.Tuple):
        vals = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            vals.append(e.value)
        return tuple(vals)
    return None
