"""`repro-lint`: AST-based static analysis for the engine's hot-path
discipline.

PR 5 made the serving hot path fast by *convention*: O(1) jitted
dispatches per step, one end-of-step host sync, donated cache buffers,
pow2-bucketed jit cache keys. This package turns those conventions into
machine-checked rules over the repo's ASTs — no imports, no tracing,
stdlib-only (`ast`), so the lint lane runs in milliseconds without JAX.

Rules (see README.md for the full catalog):

* NFP001  host sync reachable from a hot root outside the declared
          sync point
* NFP002  read of a buffer after it was donated to a jitted callable
* NFP003  jit-wrapper cache keyed on a raw integer not derived from a
          pow2/bucket helper
* NFP004  pallas_call BlockSpec/grid hygiene (index-map arity,
          divisibility asserts, interpret fallback)
* NFP005  Python control flow on traced values inside jitted bodies

Inline directives (comments):

* ``# nfp: ignore[NFP001] <reason>``  suppress a finding on this line
  (or the next line when the directive stands alone); the reason is
  mandatory
* ``# nfp: hot-path``    on/above a ``def``: treat it as an NFP001 root
* ``# nfp: sync-point``  on/above a ``def``: the function IS the
  declared host sync; NFP001 skips its body
"""

from repro.analysis.astutil import Directive, Module, load_module
from repro.analysis.callgraph import CallGraph, FuncInfo
from repro.analysis.rules import Finding

__all__ = ["Directive", "Module", "load_module", "CallGraph", "FuncInfo",
           "Finding", "run_analysis", "main"]


def __getattr__(name):
    # lazy: importing .cli here would pre-load it into sys.modules and
    # make `python -m repro.analysis.cli` warn under runpy
    if name in ("main", "run_analysis"):
        from repro.analysis import cli
        return getattr(cli, name)
    raise AttributeError(name)
