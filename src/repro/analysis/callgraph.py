"""Lightweight project-wide call graph.

Resolution is best-effort and deliberately over-approximate — for a
hot-path reachability analysis a spurious edge only widens the audit
surface (and the suppression/baseline mechanisms absorb noise), while a
missing edge silently exempts code from the rules:

* ``name(...)``            same-module function, else a from-import
* ``self.m(...)``          methods named ``m`` in the same class first,
                           else any project function named ``m``
* ``alias.f(...)``         resolved through the import alias map
                           (``M.paged_step`` with ``import ... as M``)
* ``anything.m(...)``      any project function/method named ``m``
                           (duck-typed attribute calls: ``self.blocks
                           .ensure`` reaches ``BlockManager.ensure``)

Calls inside ``lambda`` bodies are attributed to the enclosing
function; nested ``def``s are their own nodes with an implicit edge
from the encloser (defining a closure that escapes via ``jax.jit``
makes it part of the encloser's behavior).
"""

from __future__ import annotations

import ast
import collections
import dataclasses

from repro.analysis.astutil import Module, dotted_path

FuncDef = ast.FunctionDef | ast.AsyncFunctionDef


@dataclasses.dataclass
class FuncInfo:
    qualname: str              # "repro.serving.engine.Engine.step"
    name: str
    module: Module
    node: FuncDef
    cls: str | None            # enclosing class name, if a method


class CallGraph:
    def __init__(self, modules: list[Module]):
        self.modules = modules
        self.funcs: dict[str, FuncInfo] = {}
        self.by_name: dict[str, list[FuncInfo]] = collections.defaultdict(list)
        for mod in modules:
            self._collect(mod)
        self.edges: dict[str, set[str]] = {q: self._edges_of(fi)
                                           for q, fi in self.funcs.items()}

    # -- collection -----------------------------------------------------------
    def _collect(self, mod: Module) -> None:
        def visit(node: ast.AST, prefix: str, cls: str | None) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncDef):
                    qual = f"{prefix}.{child.name}"
                    fi = FuncInfo(qual, child.name, mod, child, cls)
                    self.funcs[qual] = fi
                    self.by_name[child.name].append(fi)
                    visit(child, qual, cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}.{child.name}", child.name)
                else:
                    visit(child, prefix, cls)
        visit(mod.tree, mod.name, None)

    # -- edges ----------------------------------------------------------------
    def _resolve(self, call: ast.Call, fi: FuncInfo) -> set[str]:
        mod = fi.module
        f = call.func
        out: set[str] = set()
        if isinstance(f, ast.Name):
            local = f"{mod.name}.{f.id}"
            if local in self.funcs:
                return {local}
            imported = mod.from_imports.get(f.id)
            if imported and imported in self.funcs:
                return {imported}
            return out
        if isinstance(f, ast.Attribute):
            # alias.method via the import map
            path = dotted_path(f)
            if path:
                head, _, rest = path.partition(".")
                if rest and head in mod.mod_aliases:
                    cand = f"{mod.mod_aliases[head]}.{rest}"
                    if cand in self.funcs:
                        return {cand}
                    if cand.startswith(("numpy.", "jax.", "time.")):
                        return out       # known-external: don't duck-type
            # self.m -> same-class methods first
            if isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and fi.cls:
                same = [c for c in self.by_name.get(f.attr, ())
                        if c.cls == fi.cls and c.module is mod]
                if same:
                    return {c.qualname for c in same}
            # duck-typed: every project METHOD with this attribute name.
            # Module-level functions are excluded — they are called by
            # name or module alias (both handled above), and matching
            # them here would glue every `eng.run()` to every
            # benchmark's top-level `run()`.
            out.update(c.qualname for c in self.by_name.get(f.attr, ())
                       if c.cls is not None)
        return out

    def _edges_of(self, fi: FuncInfo) -> set[str]:
        targets: set[str] = set()

        def scan(node: ast.AST) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncDef):
                    # nested def: its own node, implicit edge
                    targets.add(f"{fi.qualname}.{child.name}")
                    continue
                if isinstance(child, ast.Call):
                    targets.update(self._resolve(child, fi))
                scan(child)
        scan(fi.node)
        return targets

    # -- queries --------------------------------------------------------------
    def reachable(self, roots: set[str],
                  stop: set[str] = frozenset()) -> set[str]:
        """Qualnames reachable from `roots` (roots included), never
        entering — or traversing through — `stop` nodes."""
        seen: set[str] = set()
        work = [r for r in roots if r in self.funcs and r not in stop]
        while work:
            q = work.pop()
            if q in seen:
                continue
            seen.add(q)
            work.extend(t for t in self.edges.get(q, ())
                        if t not in seen and t not in stop)
        return seen

    def match_roots(self, patterns: list[str]) -> set[str]:
        """Resolve root specs: exact qualname, or suffix match (so
        "Engine.step" works without the full module path)."""
        out: set[str] = set()
        for pat in patterns:
            if pat in self.funcs:
                out.add(pat)
                continue
            out.update(q for q in self.funcs
                       if q.endswith("." + pat) or q == pat)
        return out
