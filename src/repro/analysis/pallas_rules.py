"""NFP004 (pallas_call hygiene) and NFP005 (traced control flow).

Both rules guard trace-time failure modes that only surface on the
backend you are NOT developing on: a BlockSpec index-map whose arity
drifts from the grid fails at lowering on TPU but may pass in
interpret mode; Python `if`/`while`/`assert` on a traced value raises
`TracerBoolConversionError` only once the enclosing jit actually
traces that path.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import Module, resolve_call_target, unparse_short
from repro.analysis.callgraph import CallGraph, FuncDef, FuncInfo
from repro.analysis.rules import Finding, _body_nodes, _device_names, _is_jit_call

_PALLAS_CALL = "jax.experimental.pallas.pallas_call"
_PREFETCH = "PrefetchScalarGridSpec"


def _kwarg(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _local_env(fn: FuncDef) -> dict[str, ast.AST]:
    """name -> RHS for single-target Name assignments (last wins)."""
    env: dict[str, ast.AST] = {}
    for node in _body_nodes(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            env[node.targets[0].id] = node.value
    return env


def _deref(expr: ast.AST | None, env: dict[str, ast.AST],
           depth: int = 3) -> ast.AST | None:
    while depth and isinstance(expr, ast.Name) and expr.id in env:
        expr = env[expr.id]
        depth -= 1
    return expr


def _is_ceil_div(expr: ast.AST) -> bool:
    """`-(-a // b)` ceil-division over-covers instead of truncating."""
    return (isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub)
            and isinstance(expr.operand, ast.BinOp)
            and isinstance(expr.operand.op, ast.FloorDiv))


class PallasBlockSpecRule:
    """NFP004: every `pl.pallas_call` must (a) give each BlockSpec
    index-map exactly grid-arity (+ num_scalar_prefetch) parameters,
    (b) back floor-divided grid sizes with a divisibility assert (a
    truncated tail silently drops data), and (c) thread an `interpret=`
    fallback so the kernel runs off-TPU — hardcoding it True/False
    either never exercises the compiled path or cannot run in CI."""
    rule = "NFP004"

    def __init__(self, graph: CallGraph):
        self.graph = graph

    def run(self) -> list[Finding]:
        out: list[Finding] = []
        for qual in sorted(self.graph.funcs):
            fi = self.graph.funcs[qual]
            for node in _body_nodes(fi.node):
                if isinstance(node, ast.Call) \
                        and resolve_call_target(node, fi.module) == _PALLAS_CALL:
                    out.extend(self._check(node, fi))
        return out

    def _check(self, call: ast.Call, fi: FuncInfo) -> list[Finding]:
        mod, env = fi.module, _local_env(fi.node)
        out: list[Finding] = []

        def flag(node: ast.AST, msg: str) -> None:
            out.append(Finding(self.rule, mod.rel, node.lineno,
                               node.col_offset, msg, fi.qualname))

        grid_expr, extra = _kwarg(call, "grid"), 0
        in_specs, out_specs = _kwarg(call, "in_specs"), _kwarg(call, "out_specs")
        gs = _deref(_kwarg(call, "grid_spec"), env)
        if isinstance(gs, ast.Call) \
                and (resolve_call_target(gs, mod) or "").endswith(_PREFETCH):
            grid_expr = _kwarg(gs, "grid")
            nsp = _deref(_kwarg(gs, "num_scalar_prefetch"), env)
            if isinstance(nsp, ast.Constant) and isinstance(nsp.value, int):
                extra = nsp.value
            in_specs, out_specs = _kwarg(gs, "in_specs"), _kwarg(gs, "out_specs")

        grid = _deref(grid_expr, env)
        arity = len(grid.elts) if isinstance(grid, ast.Tuple) else None

        # (a) index-map arity
        if arity is not None:
            for spec in self._blockspecs(in_specs, env, mod) \
                    + self._blockspecs(out_specs, env, mod):
                imap = spec.args[1] if len(spec.args) > 1 \
                    else _kwarg(spec, "index_map")
                if isinstance(imap, ast.Lambda):
                    n = len(imap.args.args)
                    if n != arity + extra:
                        flag(spec, f"BlockSpec index-map takes {n} args but "
                                   f"the grid has {arity} dims"
                                   + (f" + {extra} scalar-prefetch operands"
                                      if extra else "")
                                   + f" (expected {arity + extra})")

        # (b) floor-divided grid sizes need a divisibility assert
        if isinstance(grid, ast.Tuple):
            for elt in grid.elts:
                d = _deref(elt, env)
                if isinstance(d, ast.BinOp) and isinstance(d.op, ast.FloorDiv) \
                        and not self._has_divisibility_assert(fi.node, d):
                    flag(elt, f"grid size `{unparse_short(d)}` floor-divides "
                              f"without an `x % y == 0` assert — a non-"
                              f"divisible tail is silently dropped")
                elif _is_ceil_div(d) or d is None:
                    continue

        # (c) interpret fallback
        interp = _kwarg(call, "interpret")
        if interp is None:
            flag(call, "pallas_call without an `interpret=` fallback — the "
                       "kernel cannot run (or be CI-tested) off-TPU")
        elif isinstance(interp, ast.Constant):
            flag(interp, f"pallas_call hardcodes interpret={interp.value!r}; "
                         f"gate it on the platform or a caller flag")
        return out

    def _blockspecs(self, specs: ast.AST | None, env: dict[str, ast.AST],
                    mod: Module) -> list[ast.Call]:
        specs = _deref(specs, env)
        if specs is None:
            return []
        elts = specs.elts if isinstance(specs, (ast.List, ast.Tuple)) \
            else [specs]
        out = []
        for e in elts:
            e = _deref(e, env)
            if isinstance(e, ast.Call) \
                    and (resolve_call_target(e, mod) or "").endswith("BlockSpec"):
                out.append(e)
        return out

    def _has_divisibility_assert(self, fn: FuncDef, div: ast.BinOp) -> bool:
        want_l, want_r = ast.unparse(div.left), ast.unparse(div.right)
        for node in _body_nodes(fn):
            if not isinstance(node, ast.Assert):
                continue
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod) \
                        and ast.unparse(sub.left) == want_l \
                        and ast.unparse(sub.right) == want_r:
                    return True
        return False


class TracedControlFlowRule:
    """NFP005: inside a jitted (or pallas-kernel) body, Python
    `if`/`while`/`assert` on a value produced by a jnp/jax op forces the
    tracer through `bool()` — `TracerBoolConversionError` at trace
    time, or, for `assert` under `python -O`, silent no-op. Static
    control flow on configs/strings is fine and is not flagged."""
    rule = "NFP005"

    def __init__(self, graph: CallGraph):
        self.graph = graph
        self.jitted = self._jitted_closure()

    def _seeds(self) -> set[str]:
        seeds: set[str] = set()
        for qual, fi in self.graph.funcs.items():
            for dec in fi.node.decorator_list:
                src = unparse_short(dec, limit=120)
                # @jax.jit / @jit / @partial(jax.jit, ...) / @jax.jit(...)
                if "jit" in src.split("(")[0] or \
                        (src.startswith(("functools.partial(", "partial("))
                         and ".jit" in src):
                    seeds.add(qual)
        # functions passed by name to jax.jit(...) / pl.pallas_call(...)
        for qual, fi in self.graph.funcs.items():
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                tgt = resolve_call_target(node, fi.module) or ""
                if not (_is_jit_call(node, fi.module) or tgt == _PALLAS_CALL
                        or tgt.endswith("partial")):
                    continue
                for a in node.args:
                    if isinstance(a, ast.Name):
                        seeds.update(c.qualname for c in
                                     self.graph.by_name.get(a.id, ())
                                     if c.module is fi.module)
        return seeds

    def _jitted_closure(self) -> set[str]:
        return self.graph.reachable(self._seeds())

    def run(self) -> list[Finding]:
        out: list[Finding] = []
        for qual in sorted(self.jitted):
            fi = self.graph.funcs[qual]
            out.extend(self._scan(fi))
        return out

    def _scan(self, fi: FuncInfo) -> list[Finding]:
        mod = fi.module
        device = _device_names(fi.node, mod)
        out: list[Finding] = []
        for node in _body_nodes(fi.node):
            if isinstance(node, (ast.If, ast.While)):
                test, kind = node.test, type(node).__name__.lower()
            elif isinstance(node, ast.Assert):
                test, kind = node.test, "assert"
            else:
                continue
            why = self._traced_reason(test, mod, device)
            if why:
                out.append(Finding(
                    self.rule, mod.rel, node.lineno, node.col_offset,
                    f"`{kind}` on traced value inside a jitted body "
                    f"({why}) — use jnp.where/lax.cond or hoist the check "
                    f"out of the traced region", fi.qualname))
        return out

    _STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes"}
    _STATIC_CMP = (ast.Is, ast.IsNot, ast.In, ast.NotIn)

    def _traced_reason(self, test: ast.AST, mod: Module,
                       device: set[str]) -> str | None:
        """A test is traced when it reads the VALUE of a jnp/jax result.
        Shape/dtype attributes, `is (not) None`, and key-membership
        checks are static even on traced operands and stay legal."""

        def scan(node: ast.AST, exempt: bool) -> str | None:
            if isinstance(node, ast.Attribute) \
                    and node.attr in self._STATIC_ATTRS:
                return scan(node.value, True)
            if isinstance(node, ast.Compare) \
                    and all(isinstance(op, self._STATIC_CMP)
                            for op in node.ops):
                exempt = True
            if isinstance(node, ast.Call) and not exempt:
                tgt = resolve_call_target(node, mod) or ""
                if tgt.startswith(("jax.numpy.", "jax.lax.", "jax.nn.")):
                    return f"`{unparse_short(node)}` is traced"
            if isinstance(node, ast.Name) and not exempt \
                    and node.id in device:
                return f"`{node.id}` was produced by a jnp/jax op"
            for child in ast.iter_child_nodes(node):
                why = scan(child, exempt)
                if why:
                    return why
            return None

        return scan(test, False)
