"""Tensor-parallel placement for the paged serving engine.

One engine drives an N-chip ("model",)-axis mesh (`launch.mesh
.make_serving_mesh`) as ONE logical device: weights and the paged KV
pool are committed to sharded layouts at engine construction, and every
per-step dispatch stays a single pjit program whose partitioning GSPMD
derives from those committed operands. The host-side scheduler
(BlockManager, chunk planner, controller) is untouched — it never knew
about devices in the first place.

Layout (axis table in serving/README.md):

  NestedFP planar weights   `launch.sharding.param_spec` — attention
                            projections head-parallel, MLP column/row
                            parallel, with the K/V-replication fallback
                            when kv_heads % model != 0 (gemma3).
  paged KV pool             `launch.sharding.paged_cache_spec` — GQA
                            K/V (and NestedKV byte) planes sharded on
                            the KV-head axis, MLA latents and conv_bc
                            replicated, SSM state head-sharded.
  block tables              replicated (`BlockManager.mirror_sharding`)
                            — a few KiB of int32 every shard needs to
                            resolve its gathers; the incremental
                            dirty-entry scatter updates all replicas
                            from ONE logical flush per step.
  per-step operands         replicated (tokens, q_offset, kv_len,
                            logit_position — pinned below so GSPMD
                            never tries to partition control data).

`sharded_paged_step` is the hot-path entry point registered with
repro-lint: it must stay free of host syncs exactly like the
single-device `model.paged_step` it wraps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M


def replicated(mesh) -> NamedSharding:
    """The 'every shard holds all of it' placement for tiny host-built
    step inputs (tables, token ids, row indices)."""
    return NamedSharding(mesh, P())


def put_replicated(mesh, a):
    """Place a host array on the mesh fully replicated. The tiered-KV
    spill/restore operands (block-id vectors, stacked host plane bytes)
    go through here so their uploads carry an explicit replicated
    sharding — GSPMD must never partition control data, and the restore
    scatter's donated pool keeps whatever sharding the pool already has."""
    return jax.device_put(a, replicated(mesh))


def shard_serving_params(params, cfg, mesh):
    """Commit a `to_serving` parameter tree onto the mesh via the
    training-path resolver (`param_spec` sees the same dict keys —
    wq/wk/... — through NestedLinearParams/NestedTensor pytree nodes,
    and byte planes have the same shapes as the f16 weights they
    encode)."""
    from repro.launch import sharding as SH
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    return jax.device_put(
        params, SH.tree_shardings(shapes, mesh, SH.param_spec, cfg))


def sharded_paged_step(mesh, rt, params, cfg, tokens, caches, block_tables,
                       *, q_offset, kv_len, block_size, logit_position=None,
                       slot=None, return_logits: bool = False,
                       sample_all: bool = False):
    """`model.paged_step` as a mesh program: same signature (after the
    leading mesh), same semantics, one logical dispatch. Small per-step
    operands are pinned replicated so partitioning lives entirely in the
    weight/pool operands; the sampled ids come back replicated, making
    the engine's single end-of-step sync a local host read.
    `sample_all` (speculative verification: per-column argmax over a
    C=K+1 chunk) passes straight through — the (B, C) ids it returns are
    pinned replicated exactly like the (B,) decode ids."""
    rep = NamedSharding(mesh, P())

    def pin(x):
        return jax.lax.with_sharding_constraint(jnp.asarray(x), rep)

    out, new_caches = M.paged_step(
        rt, params, cfg, pin(tokens), caches, pin(block_tables),
        q_offset=pin(q_offset), kv_len=pin(kv_len), block_size=block_size,
        logit_position=None if logit_position is None
        else pin(logit_position),
        slot=slot, return_logits=return_logits, sample_all=sample_all)
    return jax.lax.with_sharding_constraint(out, rep), new_caches
