"""Continuous-batching serving engine with per-iteration dual precision.

ORCA-style iteration-level scheduling on a BLOCK-PAGED KV cache: each
engine step (a) schedules prompt-prefill CHUNKS up to a bounded token
budget — interleaved with decode so a long queued prompt no longer
stalls every active decode's TPOT — and (b) advances all active slots by
one token (batched decode). Admission is driven by free KV blocks rather
than free slots; when decode growth exhausts the pool, the youngest
sequence is preempted (blocks released, request requeued for recompute).
The DualPrecisionController picks FP16 or FP8 per iteration; because
NestedFP serves both precisions from the same weight buffers the switch
costs nothing — the engine simply dispatches to the other pre-compiled
executable (paper §5.3 "per-iteration precision switching"), and the
measured wall time of every step feeds the controller's p90 tracker.

EVERY decoder-only family runs the paged path — there is ONE scheduling
path. Cache layouts are per-family descriptors (kvcache.py
`CacheDescriptor`): GQA K/V planes (incl. the byte-planar NestedKV
layout on paged blocks), MLA `c_kv`+`k_rope` latent planes (absorbed
latent attention over gathered blocks), and hybrid/ssm descriptors that
pair paged shared-attention planes with slot-resident Mamba2 state
(claimed per-slot via SlotManager in lockstep with the block tables and
zeroed at (re-)admission). Because MLA latent and hybrid shared-attn
blocks live in the same pool, the controller's `free_block_frac` FP8
trigger sees deepseek/zamba-class memory pressure too. The legacy
fixed-slot scheduling path (`_admit_legacy`/`_decode_legacy`) is
retired.

Recurrent families (ssm/hybrid) prefill with EXACT-length chunks (pad
tokens would be absorbed into the state) and disable prefix caching (a
cached KV prefix cannot stand in for slot-resident SSM state); batched
decode masks state writes on inactive rows.

Sliding-window archs (gemma3's 5:1 local:global layout) serve with one
block table PER WINDOW GROUP: local-layer blocks that slide fully out
of every future query's window are freed back to the pool mid-
generation (`BlockManager.slide_window`, invoked on every ensure) while
global-layer blocks stay pinned, so `free_block_frac` — and with it the
controller's memory-pressure FP8 trigger and the admission watermark —
reflects HONEST headroom instead of phantom pressure from dead
local-layer KV. Prefix matching is group-aware: global groups match the
full from-root chain, local groups only need (and only attach) the
blocks covering the resume position's lookback window.
`window_reclaim=False` keeps the group split but never slides — the
every-block-resident baseline the tests compare against.

Copy-on-write prefix caching (gqa/mla, on by default): at admission
the engine matches the longest cached full-block prefix of the request's
token stream (kvcache.py chain-hash index), attaches those blocks with
zero recompute, and starts chunked prefill at the matched offset —
always recomputing at least the final prompt token so the first-token
logit is produced. Before any chunk or decode write lands, shared
write-target blocks are COW-forked (`cow_for_write`) and their bytes
copied in the physical pool by one jitted block-copy; retire/preempt
decref blocks instead of freeing them, parking reusable prefixes in an
LRU pool that is reclaimed before preemption ever triggers. The paged
attention read path gathers keys through the block table in logical
order, so shared physical blocks are transparent to `paged_step` and the
planar decode kernel alike. `prefix_cache_stats()` reports hit-rate and
blocks saved.

N-gram speculative decoding (opt-in via `speculate=`): each decode row
may carry up to K drafted tokens proposed by a host-side suffix n-gram
match over the request's OWN token history (serving/speculate.py — no
draft model, no extra dispatch). The batched decode then runs as one
ragged C=K+1 `paged_step` chunk with per-column greedy argmax
(`sample_all=True`), and the longest accepted draft prefix is selected
ON DEVICE next to the fused sampling — the end-of-step sync pulls a
single packed `[ids | n_accepted]` array, so speculation adds zero host
syncs. Rejected draft positions are rolled back by pure block
bookkeeping (`BlockManager.truncate`: rejected writes only ever land in
COW-exclusive unregistered tail blocks, so garbage beyond the accepted
length is masked by kv_len and overwritten before it could become
valid), and the per-row draft length adapts to the measured acceptance
rate (`core.policy.AdaptiveKController` on the same `StepObservation`
stream the precision controller reads). Drafting is opportunistic and
NEVER preempts: draft extensions are clamped to `max_coverable` and
given back (truncate) if their COW fork cannot complete. Greedy outputs
are BIT-IDENTICAL with speculation on or off — drafts only decide how
many tokens one dispatch confirms, never which tokens. Recurrent
descriptors reject speculation (slot-resident SSM state cannot roll
back).

Greedy sampling; attention-family chunk lengths are bucketed and jit
caches key on (mode, bucket) with positions and slot index passed as
traced arguments, so distinct prompt lengths share one executable per
bucket (recurrent families compile per exact chunk length instead).

One-dispatch steps (host-orchestration overhead)
------------------------------------------------
The per-step host work is O(1) jitted dispatches and O(changed bytes)
host→device traffic, independent of how many sequences are prefilling
or decoding:

* ALL of a step's planned prompt chunks run as ONE batched ragged
  `paged_step` dispatch (attention-family descriptors): chunk rows are
  right-padded to a shared bucket, row count is bucketed to a power of
  two, and per-row `q_offset`/`kv_len`/`logit_position` carry the
  raggedness — executables key on (mode, rows-bucket, chunk-bucket),
  i.e. the total-chunk bucket. Disabled pad rows (kv_len=0) write to
  the trash block. Recurrent descriptors keep per-chunk dispatches
  (exact-length chunks + single-slot state routing).
* Block tables live on DEVICE (`BlockManager.device_tables()`): each
  dispatch reads the persistent mirror, and allocate/ensure/slide/COW
  mutations flush as one small jitted scatter instead of re-uploading
  the (G, n_slots, MB) array every step.
* Sampling is fused into the jitted step (`paged_step` returns argmax
  token ids), so decode pulls (B,) int32s back — not (B, vocab) floats
  — and the step's device results are synced ONCE at the end
  (`_finalize_step`); no `np.asarray` on live device values mid-step.
  A prefill that completes mid-step hands its on-device first token to
  the same step's decode through a tiny jitted overlay, never a sync.
* Caches are donated to every step dispatch, so XLA updates pools in
  place rather than copying them per step.

`stats` counts `prefill_dispatches`/`decode_dispatches`/
`aux_dispatches` and `h2d_bytes`; `benchmarks/bench_kernel_overhead.py`
turns them into the `engine_dispatch/*` rows the CI smoke asserts.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.compat import mesh_context
from repro.core.policy import (AdaptiveKController, DualPrecisionController,
                               SpeculationConfig, StepObservation)
from repro.models import model as M
from repro.models.layers import Runtime
from repro.serving import shard as SHARD
from repro.serving.kvcache import BlockManager, SlotManager
from repro.serving.speculate import NgramProposer


@dataclasses.dataclass
class Request:
    request_id: str
    tokens: list[int]
    max_new: int
    arrival_s: float = 0.0
    # generation stops the step AFTER one of these ids is emitted (the
    # stop token itself is kept in `output`, EOS-style); an accepted
    # speculative run is cut at the first stop token mid-run
    stop_tokens: tuple[int, ...] = ()
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    finished_s: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    modes: list[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefill:
    """In-flight chunked prefill. seq_tokens is the full token stream to
    re-establish in the cache — prompt plus any output generated before a
    preemption (greedy decoding makes the recompute continuation exact)."""
    req: Request
    seq_tokens: list[int]
    done: int = 0


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


# placeholder for a token whose value still lives on device; patched by
# `_finalize_step`'s single end-of-step sync before anything reads it
_PENDING = -1


class Engine:
    def __init__(self, cfg: ArchConfig, serving_params, *, n_slots: int,
                 capacity: int, controller: DualPrecisionController | None = None,
                 forced_mode: str | None = None, backend: str = "ref",
                 attn_backend: str = "ref",
                 kv_planar: bool = False,
                 clock: Callable[[], float] = time.monotonic,
                 block_size: int = 16,
                 n_blocks: int | None = None, chunk_tokens: int = 256,
                 prefix_cache: bool = True, window_reclaim: bool = True,
                 debug_invariants: bool = False, mesh=None,
                 speculate: SpeculationConfig | bool | None = None):
        # mesh (launch.mesh.make_serving_mesh): drive an N-chip
        # tensor-parallel mesh as ONE logical device — weights and the
        # paged pool are committed to sharded layouts here (serving/
        # shard.py axis table) and every step stays a single pjit
        # dispatch whose partitioning GSPMD derives from them. None
        # preserves single-device serving byte-for-byte.
        self.cfg = cfg
        self.mesh = mesh
        self.params = serving_params if mesh is None \
            else SHARD.shard_serving_params(serving_params, cfg, mesh)
        self.controller = controller
        self.forced_mode = forced_mode
        self.clock = clock
        self.n_slots = n_slots
        self.capacity = capacity
        self.chunk_tokens = chunk_tokens
        # opt-in runtime sanitizer (Engine(debug_invariants=True) or
        # NFP_DEBUG=1): audit the BlockManager's refcount/free-list/
        # table-mirror invariants after every step instead of only where
        # a test remembers to call check_invariants()
        self.debug_invariants = debug_invariants \
            or os.environ.get("NFP_DEBUG") == "1"
        self.kv_planar = kv_planar and cfg.cache_kind == "gqa"
        # raises NotImplementedError for enc-dec — engine serves
        # decoder-only archs (enc-dec is covered by dry-run + benchmarks)
        self.desc = M.cache_descriptor(cfg, planar=self.kv_planar)
        # recurrent state can't be re-attached from cached KV blocks
        prefix_cache = prefix_cache and self.desc.prefix_cacheable
        # pad tokens are invisible to attention (causal mask + trash
        # block) but would be absorbed into SSM state: recurrent
        # families prefill with exact-length chunks instead of buckets
        self._pad_chunks = not self.desc.slot_planes
        # n-gram speculative decoding (module docstring): True picks the
        # default SpeculationConfig; rejected-draft rollback is pure
        # block bookkeeping, which slot-resident recurrent state cannot
        # provide — advancing an SSM recurrence is irreversible
        if speculate:
            if self.desc.slot_planes:
                raise ValueError(
                    "speculative decoding requires rolling back rejected "
                    "positions; slot-resident recurrent state (ssm/hybrid "
                    "descriptors) cannot be truncated")
            self._spec = speculate if isinstance(speculate, SpeculationConfig) \
                else SpeculationConfig()
            self._proposer = NgramProposer(self._spec)
            self._spec_k = AdaptiveKController(self._spec)
        else:
            self._spec = None
            self._proposer = None
            self._spec_k = None
        self._spec_cache: dict[tuple[str, int], Any] = {}
        self._last_spec = (0, 0)     # (drafted, accepted) of the last step
        self.queue: collections.deque[Request] = collections.deque()
        self.active: dict[int, Request] = {}
        self.prefilling: dict[int, _Prefill] = {}
        self.finished: list[Request] = []
        self.lens = np.zeros(n_slots, np.int32)
        self.stats = {"preemptions": 0, "chunks": 0, "chunk_tokens": 0,
                      "peak_block_util": 0.0, "window_reclaimed_blocks": 0,
                      # one-dispatch accounting (bench_kernel_overhead
                      # engine_dispatch/* rows): jitted calls per phase
                      # plus host->device bytes for step inputs (block
                      # tables are counted by BlockManager separately)
                      "prefill_dispatches": 0, "decode_dispatches": 0,
                      "aux_dispatches": 0, "h2d_bytes": 0,
                      # speculative decoding (spec_stats() / bench
                      # spec/* rows): decode_rows counts row-dispatches,
                      # decode_tokens the tokens they emitted — their
                      # ratio is tokens-accepted-per-dispatch (1.0
                      # without speculation, >1 iff drafts accepted)
                      "spec_dispatches": 0, "spec_drafted": 0,
                      "spec_accepted": 0, "decode_rows": 0,
                      "decode_tokens": 0}
        self._last_step_ms: float | None = None
        # attn_backend="pallas" serves planar GQA decode through the
        # block-table scalar-prefetch kernel (layers.attention "paged");
        # anything it cannot serve falls back to the ref gather path.
        # act_quant="per_token": fp8 generation must be batch-invariant
        # under continuous batching (and speculative verification chunks)
        # — per-tensor dynamic scales would couple co-batched tokens'
        # rounding (Runtime docstring).
        self._rts = {m: Runtime(mode=m, backend=backend, dtype=jnp.float32,
                                act_quant="per_token",
                                attn_backend=None if attn_backend == "ref"
                                else attn_backend, mesh=mesh)
                     for m in ("fp16", "fp8")}
        self.block_size = block_size
        mbs = -(-capacity // block_size)
        # per-layer-group window metadata: sliding-window archs (gemma3)
        # keep one block table per group — each group allocates from its
        # own id space over the same pool array (a layer only touches
        # its group's rows of a block), so local-layer blocks can be
        # slide-freed mid-generation while global-layer blocks stay
        # pinned, at zero extra pool bytes; window_reclaim=False keeps
        # the group split but never slides (the
        # every-block-resident-forever baseline)
        gw = self.desc.group_windows
        if not window_reclaim:
            gw = (None,) * len(gw)
        if n_blocks is None:
            n_blocks = n_slots * mbs         # dense-equivalent pool by default
        self.blocks = BlockManager(n_slots, block_size, n_blocks, mbs,
                                   prefix_cache=prefix_cache,
                                   group_windows=gw,
                                   mirror_sharding=None if mesh is None
                                   else SHARD.replicated(mesh))
        # slot-resident state side (hybrid/ssm descriptors): SlotManager
        # tracks per-slot occupancy in lockstep with the block tables
        self.slot_state = SlotManager(n_slots, capacity) \
            if self.desc.slot_planes else None
        self.caches = M.init_paged_cache(
            cfg, self.blocks.n_total_blocks, block_size, n_slots=n_slots,
            planar=self.kv_planar, mesh=mesh)
        # the step entry point: identical call signature either way, so
        # the dispatch sites below never branch on the mesh. Sharded
        # mode routes through serving/shard.sharded_paged_step (a
        # repro-lint hot root), which pins the tiny control operands
        # replicated and leaves pool/weight partitioning to GSPMD.
        self._paged_step = M.paged_step if mesh is None \
            else functools.partial(SHARD.sharded_paged_step, mesh)
        # one compile per window group: src/dst are traced scalars into
        # the block axis; donating the cache lets XLA update the one
        # block in place instead of materializing a whole-pool copy per
        # COW fork. Only paged-plane subtrees are touched —
        # slot-resident state ("ssm") has a slot axis, not a block
        # axis. With per-group block id spaces a fork must copy ONLY
        # the group's layer rows: the same physical id may be live in
        # the other group with unrelated content.
        def _make_copy(layers):
            if layers is None:               # single group: all layers
                cp = lambda a, s, d: a.at[:, d].set(a[:, s])
            else:
                li = jnp.asarray(layers, jnp.int32)
                cp = lambda a, s, d: a.at[li, d].set(a[li, s])
            return jax.jit(
                lambda c, s, d: {
                    k: (jax.tree.map(lambda a: cp(a, s, d), sub)
                        if k in ("attn", "shared") else sub)
                    for k, sub in c.items()},
                donate_argnums=(0,))
        if self.desc.groups:
            self._copy_block = {gi: _make_copy(g.layers)
                                for gi, g in enumerate(self.desc.groups)}
        else:
            self._copy_block = {0: _make_copy(None)}
        if self.slot_state is not None:
            # zero one slot's recurrent state at (re-)admission
            self._zero_slot = jax.jit(
                lambda c, i: {
                    k: (jax.tree.map(lambda a: a.at[:, i].set(0), sub)
                        if k == "ssm" else sub)
                    for k, sub in c.items()},
                donate_argnums=(0,))
        # batched decode: greedy sampling fused into the step (returns
        # (n_slots,) int32 ids, not (B, vocab) logits); caches donated so
        # pools update in place
        self._decode = {
            m: jax.jit(lambda p, c, t, tab, qo, kvl, _m=m:
                       self._paged_step(
                self._rts[_m], p, cfg, t, c, tab, q_offset=qo,
                kv_len=kvl, block_size=block_size), donate_argnums=(1,))
            for m in ("fp16", "fp8")}
        self._chunk_cache: dict[tuple[str, int], Any] = {}
        self._fused_cache: dict[tuple[str, int, int], Any] = {}
        # scatter a completing prefill's on-device first token into the
        # same step's decode inputs (no host sync on the seam)
        self._overlay = jax.jit(lambda t, s, ids, r: t.at[s, 0].set(ids[r]))
        self.iteration = 0

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not req.tokens:
            raise ValueError(f"request {req.request_id}: empty prompt")
        self.queue.append(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        while (self.queue or self.active or self.prefilling) \
                and self.iteration < max_iters:
            self.step()
        return self.finished

    def block_utilization(self) -> float:
        return self.blocks.utilization()

    def prefix_cache_stats(self) -> dict:
        """Prefix-cache effectiveness: hit rate over prompt tokens looked
        up at admission, blocks saved by sharing, COW forks, LRU churn
        (all-zero for recurrent descriptors, which disable the cache)."""
        ps = self.blocks.prefix_stats
        denom = ps["lookup_tokens"]
        return {"hit_rate": ps["hit_tokens"] / denom if denom else 0.0,
                "hit_tokens": ps["hit_tokens"],
                "blocks_saved": ps["blocks_shared"],
                "cached_blocks": self.blocks.n_cached_blocks(),
                "cow_forks": ps["cow_forks"],
                "evictions": ps["evictions"]}

    def spec_stats(self) -> dict:
        """Speculation effectiveness. `tokens_accepted_per_dispatch` is
        the per-row mean tokens confirmed by one decode dispatch: exactly
        1.0 without speculation, > 1 iff drafts were accepted. All ratios
        guard their denominators — a trace that never decoded (or never
        drafted) reports 0.0, it does not raise."""
        s = self.stats
        return {"enabled": self._spec is not None,
                "spec_dispatches": s["spec_dispatches"],
                "drafted": s["spec_drafted"],
                "accepted": s["spec_accepted"],
                "acceptance_rate": s["spec_accepted"] / s["spec_drafted"]
                if s["spec_drafted"] else 0.0,
                "tokens_accepted_per_dispatch":
                s["decode_tokens"] / s["decode_rows"]
                if s["decode_rows"] else 0.0,
                "k": self._spec_k.k if self._spec_k else 0}

    # -- mode selection -------------------------------------------------------
    def _mode(self, decode_tokens: int, prefill_tokens: int,
              free_block_frac: float | None = None) -> str:
        if self.forced_mode:
            return self.forced_mode
        if self.controller is None:
            return "fp16"
        obs = StepObservation(batch_tokens=max(decode_tokens, 1),
                              queue_depth=len(self.queue),
                              measured_step_ms=self._last_step_ms,
                              prefill_tokens=prefill_tokens,
                              free_block_frac=free_block_frac)
        return self.controller.decide(obs)

    # -- step -----------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: O(1) jitted dispatches regardless of how
        many sequences are prefilling or decoding (attention families —
        recurrent descriptors dispatch per chunk), with the step's device
        results synced to host exactly once at the end.

        Under a serving mesh the dispatch/h2d counters in `stats` keep
        counting LOGICAL steps: every jitted call below is one pjit
        program spanning all shards, so `prefill_dispatches` et al. and
        `h2d_bytes` are mesh-size-invariant (asserted by the dispatch
        tests) — replication fan-out is XLA's job, not a per-shard loop
        here."""
        with (contextlib.nullcontext() if self.mesh is None
              else mesh_context(self.mesh)):
            # the ambient mesh lets shard_hint constraints inside the
            # model stack (mla absorbed-q pinning et al.) take effect;
            # all committed-operand partitioning works without it
            self._step_inner()

    def _step_inner(self) -> None:
        self.iteration += 1
        t0 = self.clock()
        plan = self._plan_chunks()
        mode = self._mode(len(self.active),
                          sum(take for _, _, take in plan),
                          free_block_frac=self.blocks.free_block_frac())
        # pending: (req, output index, device ids, row, slot) patched —
        # and EOS-checked — at the end-of-step sync; fresh: (slot,
        # device ids, row) prefills that completed this step and decode
        # below with a device-held token
        pending: list[tuple[Request, int, Any, int, int]] = []
        fresh: list[tuple[int, Any, int]] = []
        chunk_ids = self._run_chunks(mode, plan, pending, fresh)
        decode_ids, drafts = self._decode_paged(mode, chunk_ids, fresh)
        self._finalize_step(mode, pending, decode_ids, drafts)
        self._sample_peak()
        # wall time of this step feeds the controller's p90 tracker on the
        # NEXT decision (measured-latency fallback to FP8, paper §3.2)
        self._last_step_ms = (self.clock() - t0) * 1e3
        if self.debug_invariants:
            # outside the measured step window, so the controller's p90
            # and the bench rows stay honest under NFP_DEBUG=1
            self.blocks.check_invariants()

    # =========================================================================
    # paged path: chunked prefill + block-table decode
    # =========================================================================
    def _ensure_take(self, idx: int, start: int, want: int) -> int:
        """Largest chunk <= want coverable by already-owned + free blocks
        across every window group (sliding dead local blocks back into
        the pool first)."""
        bm = self.blocks
        take = bm.max_coverable(idx, start, want)
        if take <= 0 or not bm.ensure(idx, start + take):
            return 0
        return take

    def _plan_chunks(self) -> list[tuple[int, int, int]]:
        """Schedule this step's prefill work: continue in-flight prefills
        (oldest first), then admit queued requests while the chunk-token
        budget, a slot, and enough free blocks for their WHOLE prompt are
        available (the admission watermark — decode growth may still
        preempt, but admissions never immediately thrash)."""
        plan: list[tuple[int, int, int]] = []
        budget = self.chunk_tokens
        order = sorted(self.prefilling,
                       key=lambda i: self.blocks.seqs[i].admitted)
        for idx in order:
            if budget <= 0:
                break
            st = self.prefilling[idx]
            want = min(len(st.seq_tokens) - st.done, budget)
            take = self._ensure_take(idx, st.done, want)
            if take > 0:
                plan.append((idx, st.done, take))
                budget -= take
        while budget > 0 and self.queue:
            req = self.queue[0]
            seq_tokens = req.tokens + req.output
            idx = self.blocks.try_allocate(
                req.request_id, len(seq_tokens),
                req.max_new - len(req.output),
                cached_blocks=self.blocks.prefix_admit_discount(seq_tokens))
            if idx is None:
                break
            self.queue.popleft()
            if self.slot_state is not None:
                # slot-resident state side: claim the same slot index and
                # zero its recurrent state (recompute after preemption
                # must restart the recurrence from scratch)
                self.slot_state.claim(idx, req.request_id, len(seq_tokens),
                                      req.max_new - len(req.output))
                self.caches = self._zero_slot(self.caches, jnp.int32(idx))
                self.stats["aux_dispatches"] += 1
            # longest cached full-block prefix is shared (incref, zero
            # recompute); prefill starts at the matched offset but always
            # recomputes >= 1 token so the first-token logit is produced
            # (cow_for_write forks the tail block if that write would
            # land in a shared one)
            matched = self.blocks.attach_prefix(idx, seq_tokens)
            start = min(matched, len(seq_tokens) - 1)
            self.blocks.set_length(idx, start)
            st = _Prefill(req, seq_tokens, done=start)
            self.prefilling[idx] = st
            take = self._ensure_take(
                idx, start, min(len(seq_tokens) - start, budget))
            if take > 0:
                plan.append((idx, start, take))
                budget -= take
        return plan

    def _h2d(self, a: np.ndarray):
        """Host->device upload with byte accounting (engine_dispatch/*
        bench rows report bytes per step/token)."""
        self.stats["h2d_bytes"] += a.nbytes
        return jnp.asarray(a)

    def _chunk_fn(self, mode: str, bucket: int):
        """Single-row prefill chunk executable (recurrent descriptors —
        attention families batch through `_fused_fn` instead). The
        traced `slot` routes the chunk's state read/write to one state
        row; the row's block table is sliced from the device-resident
        (G, n_slots, MB) array by a traced slot index, so jit caches
        still key on (mode, bucket) alone."""
        key = (mode, bucket)
        if key not in self._chunk_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size
            slotted = self.slot_state is not None

            def fn(p, caches, tokens, tables, row, q_offset, kv_len,
                   logit_pos, slot):
                table = jax.lax.dynamic_slice_in_dim(tables, row, 1, axis=1)
                return self._paged_step(rt, p, cfg, tokens, caches, table,
                                    q_offset=q_offset, kv_len=kv_len,
                                    block_size=bs, logit_position=logit_pos,
                                    slot=slot if slotted else None)
            self._chunk_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._chunk_cache[key]

    def _fused_fn(self, mode: str, rows_bucket: int, chunk_bucket: int):
        """Batched ragged prefill executable: every planned chunk of a
        step runs as one dispatch. Rows are independent single-sequence
        chunks (per-row q_offset/kv_len/logit_position carry the
        raggedness; kv_len=0 disables pad rows); each row's block table
        is gathered from the device-resident array by a traced slot
        vector, so the jit cache keys on (mode, rows-bucket,
        chunk-bucket) — the total-chunk bucket — alone."""
        key = (mode, rows_bucket, chunk_bucket)
        if key not in self._fused_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size

            def fn(p, caches, tokens, tables, rows, q_offset, kv_len,
                   logit_pos):
                tab = jnp.take(tables, rows, axis=1)     # (G, R, MB)
                return self._paged_step(rt, p, cfg, tokens, caches, tab,
                                    q_offset=q_offset, kv_len=kv_len,
                                    block_size=bs, logit_position=logit_pos)
            self._fused_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._fused_cache[key]

    def _spec_fn(self, mode: str, cb: int):
        """Speculative verification executable: the batched decode as a
        ragged C=cb chunk (column 0 the pending token, columns 1..K the
        drafts, pad columns masked by per-row kv_len), per-column greedy
        argmax (`sample_all`), and the longest-accepted-prefix selection
        FUSED next to it — draft j survives iff it matches the argmax
        after position j-1 AND every earlier draft survived (the
        cumprod). Returns ONE packed (B, cb+1) int32 array `[ids |
        n_accepted]` so the end-of-step sync stays a single pull; the jit
        cache keys on (mode, draft-bucket) via `_bucket`, exactly like
        the prefill executables."""
        key = (mode, cb)
        if key not in self._spec_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size

            def fn(p, caches, toks, tables, qo, kvl, dlen):
                ids, new_caches = self._paged_step(
                    rt, p, cfg, toks, caches, tables, q_offset=qo,
                    kv_len=kvl, block_size=bs, sample_all=True)
                # ids[:, j] = greedy successor of position qo+j; draft
                # toks[:, j] (the input at position qo+j) is confirmed
                # iff it equals ids[:, j-1]; dlen masks pad columns
                m = (ids[:, :-1] == toks[:, 1:]) \
                    & (jnp.arange(1, cb)[None, :] <= dlen[:, None])
                n_acc = jnp.cumprod(m.astype(jnp.int32), axis=1).sum(axis=1)
                return jnp.concatenate(
                    [ids, n_acc[:, None]], axis=1), new_caches
            self._spec_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._spec_cache[key]

    def _apply_cow(self, triples: list[tuple[int, int, int]]) -> None:
        """Materialize COW forks: copy each forked block's bytes — the
        owning group's layer rows only — in the physical pool (one
        jitted scatter per group, src/dst traced)."""
        for g, src, dst in triples:
            self.caches = self._copy_block[g](
                self.caches, jnp.int32(src), jnp.int32(dst))
            self.stats["aux_dispatches"] += 1

    def _cow_or_preempt(self, idx: int, start: int, end: int) -> bool:
        """Fork shared blocks covering the write range [start, end);
        preempt youngest sequences while the pool is too exhausted to
        fork. False when `idx` itself got preempted."""
        pairs = self.blocks.cow_for_write(idx, start, end)
        while pairs is None:
            victim = self.blocks.youngest()
            if victim is None:
                raise RuntimeError("KV pool exhausted with nothing "
                                   "preemptible")
            self._preempt(victim)
            if idx not in self.prefilling and idx not in self.active:
                return False                 # preempted ourselves
            pairs = self.blocks.cow_for_write(idx, start, end)
        self._apply_cow(pairs)
        return True

    def _sample_peak(self) -> None:
        self.stats["peak_block_util"] = max(
            self.stats["peak_block_util"], self.blocks.utilization())
        self.stats["window_reclaimed_blocks"] = \
            self.blocks.window_freed_blocks

    def _run_chunks(self, mode: str, plan, pending, fresh):
        """Execute this step's planned prompt chunks. Attention-family
        descriptors fuse EVERY chunk into one batched ragged dispatch;
        recurrent descriptors dispatch per chunk (exact-length chunks,
        single-slot state routing). Returns the device array of sampled
        ids for the fused batch (None otherwise); completing rows are
        recorded in `pending`/`fresh` for the end-of-step sync."""
        if self._pad_chunks:
            return self._run_chunks_fused(mode, plan, pending, fresh)
        for idx, start, take in plan:
            # a COW-fork failure inside an earlier chunk may have
            # preempted a later plan entry — skip stale entries
            if idx in self.prefilling:
                self._run_chunk(mode, idx, start, take, pending, fresh)
        return None

    def _run_chunks_fused(self, mode: str, plan, pending, fresh):
        """ONE jitted ragged `paged_step` covers the whole chunk budget:
        rows bucketed to a power of two, chunk lengths to the max take's
        bucket; pad rows are disabled via kv_len=0 and pad columns are
        masked as before, so the fused batch is bit-identical to the
        per-chunk dispatches it replaces."""
        entries = []
        for idx, start, take in plan:
            if idx not in self.prefilling:
                continue                     # preempted by an earlier COW
            if not self._cow_or_preempt(idx, start, start + take):
                continue
            entries.append((idx, start, take))
        # a later COW fork may have preempted an earlier surviving entry
        entries = [e for e in entries if e[0] in self.prefilling]
        if not entries:
            return None
        rb = _bucket(len(entries), 1)
        cb = _bucket(max(take for _, _, take in entries))
        tokens = np.zeros((rb, cb), np.int32)
        rows = np.zeros(rb, np.int32)        # pad rows alias slot 0:
        qo = np.zeros(rb, np.int32)          # kv_len=0 masks their reads
        kvl = np.zeros(rb, np.int32)         # and trashes their writes
        lp = np.zeros(rb, np.int32)
        for r, (idx, start, take) in enumerate(entries):
            st = self.prefilling[idx]
            tokens[r, :take] = st.seq_tokens[start: start + take]
            rows[r] = idx
            qo[r] = start
            kvl[r] = start + take
            lp[r] = take - 1
        ids, self.caches = self._fused_fn(mode, rb, cb)(
            self.params, self.caches, self._h2d(tokens),
            self.blocks.device_tables(), self._h2d(rows), self._h2d(qo),
            self._h2d(kvl), self._h2d(lp))
        self.stats["prefill_dispatches"] += 1
        for idx, start, take in entries:
            self._commit_chunk(idx, start, take)
        # sample pool pressure BEFORE _finish_chunk can retire+release
        # blocks — prefill-heavy steps used to under-report the peak
        self._sample_peak()
        for r, (idx, start, take) in enumerate(entries):
            self._finish_chunk(mode, idx, ids, r, pending, fresh)
        return ids

    def _run_chunk(self, mode: str, idx: int, start: int, take: int,
                   pending, fresh) -> None:
        """Recurrent-descriptor chunk: one dispatch per chunk (pads
        would be absorbed into the SSM state, so rows cannot share a
        bucketed batch)."""
        st = self.prefilling[idx]
        if not self._cow_or_preempt(idx, start, start + take):
            return
        bucket = take                        # exact-length, no padding
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :take] = st.seq_tokens[start: start + take]
        ids, self.caches = self._chunk_fn(mode, bucket)(
            self.params, self.caches, self._h2d(toks),
            self.blocks.device_tables(), jnp.int32(idx),
            self._h2d(np.asarray([start], np.int32)),
            self._h2d(np.asarray([start + take], np.int32)),
            self._h2d(np.asarray([take - 1], np.int32)), jnp.int32(idx))
        self.stats["prefill_dispatches"] += 1
        self._commit_chunk(idx, start, take)
        self._sample_peak()                  # pre-retire, as above
        self._finish_chunk(mode, idx, ids, 0, pending, fresh)

    def _commit_chunk(self, idx: int, start: int, take: int) -> None:
        st = self.prefilling[idx]
        st.done = start + take
        self.blocks.commit(idx, st.done, st.seq_tokens)
        self.stats["chunks"] += 1
        self.stats["chunk_tokens"] += take

    def _finish_chunk(self, mode: str, idx: int, ids, row: int,
                      pending, fresh) -> None:
        """Promote a prefill whose final chunk just ran to active. Its
        first generated token is still ON DEVICE (`ids[row]`): the
        output slot is patched at the end-of-step sync, and the same
        step's decode receives it through the jitted overlay."""
        st = self.prefilling[idx]
        if st.done < len(st.seq_tokens):
            return
        req = st.req
        req.output.append(_PENDING)
        pending.append((req, len(req.output) - 1, ids, row, idx))
        now = self.clock()
        if req.first_token_s is None:
            req.first_token_s = now
        req.token_times.append(now)
        req.modes.append(mode)
        self.lens[idx] = len(st.seq_tokens)
        self.active[idx] = req
        del self.prefilling[idx]
        self._maybe_retire(idx, now)
        if idx in self.active:
            fresh.append((idx, ids, row))

    def _preempt(self, victim: int) -> None:
        """vLLM-style recompute preemption: drop the victim's blocks and
        requeue its request at the FRONT of the queue; on re-admission it
        prefills prompt+generated-so-far and continues exactly."""
        self.stats["preemptions"] += 1
        if victim in self.active:
            req = self.active.pop(victim)
        else:
            req = self.prefilling.pop(victim).req
        self.blocks.release(victim)
        if self.slot_state is not None:
            self.slot_state.release(victim)
        self.lens[victim] = 0
        self.queue.appendleft(req)

    def _retire(self, idx: int, now: float) -> None:
        req = self.active.pop(idx)
        req.finished_s = now
        self.finished.append(req)
        self.blocks.release(idx)
        if self.slot_state is not None:
            self.slot_state.release(idx)
        self.lens[idx] = 0

    def _maybe_retire(self, idx: int, now: float) -> None:
        req = self.active[idx]
        # NOTE length >= capacity (not length+1): position `length` is the
        # next write target, so a row is live while length < capacity —
        # the old `+1` retired sequences one writable position early.
        # Stop-token retirement reads the LAST emitted token only: the
        # speculative multi-token path already cuts its emission at the
        # first stop token, so output[-1] is the one place EOS can live
        # (_PENDING placeholders are not yet tokens and never match).
        eos = bool(req.stop_tokens) and bool(req.output) \
            and req.output[-1] != _PENDING \
            and req.output[-1] in req.stop_tokens
        if eos or len(req.output) >= req.max_new \
                or self.lens[idx] >= self.capacity:
            self._retire(idx, now)

    def _draft(self) -> dict[int, list[int]]:
        """Propose n-gram drafts per active row and secure KV coverage
        for their writes at positions L+1..L+K. Drafting NEVER preempts:
        the draft is clamped to what the pool can cover without evicting
        anyone (`max_coverable`), and if the COW fork for the extension
        cannot complete the extension is given back (`truncate`) and the
        row runs as a plain one-token decode. Rows whose pending input
        token still lives on device (fresh prefills) cannot be matched
        against and draft nothing this step."""
        k = self._spec_k.decide(StepObservation(
            batch_tokens=max(len(self.active), 1),
            queue_depth=len(self.queue),
            measured_step_ms=self._last_step_ms,
            spec_drafted=self._last_spec[0],
            spec_accepted=self._last_spec[1]))
        drafts: dict[int, list[int]] = {}
        bm = self.blocks
        for idx, req in self.active.items():
            if req.output[-1] == _PENDING:
                continue
            L = int(self.lens[idx])
            # position L's write and this step's guaranteed token are
            # already budgeted — clamp drafts to what's left of the
            # output budget and the cache capacity beyond them
            budget = min(k, req.max_new - len(req.output) - 1,
                         self.capacity - L - 1)
            if budget <= 0:
                continue
            d = self._proposer.propose(req.tokens + req.output, budget)
            if d:
                d = d[:bm.max_coverable(idx, L + 1, len(d))]
            if not d:
                continue
            ok = bm.ensure(idx, L + 1 + len(d))
            assert ok, idx           # max_coverable guarantees coverage
            pairs = bm.cow_for_write(idx, L + 1, L + 1 + len(d))
            if pairs is None:
                bm.truncate(idx, L + 1)
                continue
            self._apply_cow(pairs)
            drafts[idx] = d
        return drafts

    def _decode_paged(self, mode: str, chunk_ids, fresh):
        """Dispatch the batched decode; returns (device ids, drafts) —
        ids None when nothing is active, drafts None for a plain
        one-token step. With speculation enabled and at least one row
        drafting, the decode runs through `_spec_fn` as a ragged C=K+1
        chunk instead (same single dispatch, packed [ids | n_accepted]
        result). Host bookkeeping for the decoded tokens happens in
        `_finalize_step` after the single end-of-step sync."""
        # grow each active row's block table to cover the incoming write
        # at position lens[idx] and COW-fork it if shared; preempt
        # youngest sequences on exhaustion
        for idx in sorted(self.active):
            while idx in self.active:
                if self.blocks.ensure(idx, int(self.lens[idx]) + 1):
                    if self._cow_or_preempt(idx, int(self.lens[idx]),
                                            int(self.lens[idx]) + 1):
                        break
                    continue                 # preempted (maybe ourselves)
                victim = self.blocks.youngest()
                if victim is None:
                    raise RuntimeError("KV pool exhausted with nothing "
                                       "preemptible")
                self._preempt(victim)
        self._sample_peak()                  # allocation peak, pre-retire
        if not self.active:
            return None, None
        drafts = self._draft() if self._spec is not None else {}
        kmax = max(map(len, drafts.values()), default=0)
        # no row drafted: dispatch the plain C=1 executable — identical
        # to speculation-off (under attn_backend="pallas" it keeps the
        # single-query decode kernel, which the C>1 chunk cannot use)
        cb = _bucket(kmax + 1, 1) if kmax else 1
        tokens = np.zeros((self.n_slots, cb), np.int32)
        q_off = np.zeros(self.n_slots, np.int32)
        kvl = np.zeros(self.n_slots, np.int32)   # 0 disables inactive rows
        dlen = np.zeros(self.n_slots, np.int32)
        for idx, req in self.active.items():
            if req.output[-1] != _PENDING:
                tokens[idx, 0] = req.output[-1]
            d = drafts.get(idx)
            if d:
                tokens[idx, 1:1 + len(d)] = d
                dlen[idx] = len(d)
            q_off[idx] = self.lens[idx]
            kvl[idx] = self.lens[idx] + 1 + dlen[idx]
        toks = self._h2d(tokens)
        fresh = [(s, a, r) for s, a, r in fresh if s in self.active]
        if fresh and chunk_ids is not None:
            # fused path: every completing prefill's first token lives in
            # ONE device array — overlay them all with a single jitted
            # scatter instead of syncing mid-step
            slots = np.asarray([s for s, _, _ in fresh], np.int32)
            rows = np.asarray([r for _, _, r in fresh], np.int32)
            toks = self._overlay(toks, self._h2d(slots), chunk_ids,
                                 self._h2d(rows))
            self.stats["aux_dispatches"] += 1
        elif fresh:
            # recurrent path: per-chunk ids arrays, one overlay each
            for s, a, r in fresh:
                toks = self._overlay(
                    toks, self._h2d(np.asarray([s], np.int32)), a,
                    self._h2d(np.asarray([r], np.int32)))
                self.stats["aux_dispatches"] += 1
        if kmax:
            ids, self.caches = self._spec_fn(mode, cb)(
                self.params, self.caches, toks, self.blocks.device_tables(),
                self._h2d(q_off), self._h2d(kvl), self._h2d(dlen))
            self.stats["decode_dispatches"] += 1
            self.stats["spec_dispatches"] += 1
            return ids, drafts
        ids, self.caches = self._decode[mode](
            self.params, self.caches, toks, self.blocks.device_tables(),
            self._h2d(q_off), self._h2d(kvl))
        self.stats["decode_dispatches"] += 1
        return ids, None

    # nfp: sync-point
    def _finalize_step(self, mode: str, pending, decode_ids,
                       drafts=None) -> None:
        """The step's ONLY device->host sync: pull the sampled token ids
        (a few int32s, not logits), patch pending prefill outputs, then
        run decode bookkeeping — commit() must hash REAL token values,
        so it happens strictly after the patch.

        A patched pending token that is a stop token retires its row
        HERE, before decode bookkeeping: the row's same-step decode
        result is discarded (its position-L write went to an exclusive
        unregistered tail block, so releasing is clean) — previously a
        first-token EOS decoded on to max_new.

        Speculative steps (`drafts` non-None) emit per row the accepted
        draft prefix plus the model's next token — `[ids | n_acc]`
        packed by `_spec_fn` — cut at the first stop token and the
        max_new budget; `BlockManager.truncate` gives back the blocks
        covering rejected positions, and one commit() both registers any
        newly-filled blocks (a multi-token emission can fill several)
        and advances the length. The LAST emitted token is never in the
        cache — it is the next step's input, exactly as in plain
        decode."""
        nxt = None if decode_ids is None else np.asarray(decode_ids)
        now = self.clock()
        for req, pos, ids, row, idx in pending:
            req.output[pos] = int(np.asarray(ids)[row])
            if req.output[pos] in req.stop_tokens \
                    and self.active.get(idx) is req:
                self._retire(idx, now)
        if nxt is None:
            return
        if drafts is None:
            for idx, req in list(self.active.items()):
                self.lens[idx] += 1
                n = int(self.lens[idx])
                if n % self.block_size == 0:
                    # tail block just filled: register it in the prefix
                    # index (generated content is reusable too — replays
                    # after preemption and shared multi-turn history)
                    self.blocks.commit(idx, n,
                                       (req.tokens + req.output)[:n])
                else:
                    self.blocks.set_length(idx, n)
                req.output.append(int(nxt[idx]))
                req.token_times.append(now)
                req.modes.append(mode)
                self.stats["decode_rows"] += 1
                self.stats["decode_tokens"] += 1
                self._maybe_retire(idx, now)
            if self._spec is not None:
                self._last_spec = (0, 0)
            return
        drafted_total = accepted_total = 0
        for idx, req in list(self.active.items()):
            d = drafts.get(idx, ())
            n_acc = int(nxt[idx, -1]) if d else 0
            out = [int(t) for t in nxt[idx, :n_acc + 1]]
            drafted_total += len(d)
            accepted_total += n_acc
            # EOS stops an accepted run MID-RUN: everything after the
            # first stop token is discarded (never emitted), and the
            # output budget bounds the emission the same way
            for j, t in enumerate(out):
                if t in req.stop_tokens:
                    out = out[:j + 1]
                    break
            out = out[:req.max_new - len(req.output)]
            new_n = int(self.lens[idx]) + len(out)
            # rollback: drop the blocks covering rejected positions
            # (their writes landed in COW-exclusive unregistered blocks;
            # what survives inside the kept tail block beyond new_n is
            # masked by kv_len and overwritten before it can be read)
            self.blocks.truncate(idx, new_n)
            self.blocks.commit(idx, new_n,
                               (req.tokens + req.output + out)[:new_n])
            self.lens[idx] = new_n
            req.output.extend(out)
            req.token_times.extend([now] * len(out))
            req.modes.extend([mode] * len(out))
            self.stats["decode_rows"] += 1
            self.stats["decode_tokens"] += len(out)
            self._maybe_retire(idx, now)
        self.stats["spec_drafted"] += drafted_total
        self.stats["spec_accepted"] += accepted_total
        self._last_spec = (drafted_total, accepted_total)

