"""Continuous-batching serving engine with per-iteration dual precision.

ORCA-style iteration-level scheduling on a BLOCK-PAGED KV cache: each
engine step (a) schedules prompt-prefill CHUNKS up to a bounded token
budget — interleaved with decode so a long queued prompt no longer
stalls every active decode's TPOT — and (b) advances all active slots by
one token (batched decode). Admission is driven by free KV blocks rather
than free slots; when decode growth exhausts the pool, the youngest
sequence is preempted (blocks released, request requeued for recompute).
The DualPrecisionController picks FP16 or FP8 per iteration; because
NestedFP serves both precisions from the same weight buffers the switch
costs nothing — the engine simply dispatches to the other pre-compiled
executable (paper §5.3 "per-iteration precision switching"), and the
measured wall time of every step feeds the controller's p90 tracker.

EVERY decoder-only family runs the paged path — there is ONE scheduling
path. Cache layouts are per-family descriptors (kvcache.py
`CacheDescriptor`): GQA K/V planes (incl. the byte-planar NestedKV
layout on paged blocks), MLA `c_kv`+`k_rope` latent planes (absorbed
latent attention over gathered blocks), and hybrid/ssm descriptors that
pair paged shared-attention planes with slot-resident Mamba2 state
(claimed per-slot via SlotManager in lockstep with the block tables and
zeroed at (re-)admission). Because MLA latent and hybrid shared-attn
blocks live in the same pool, the controller's `free_block_frac` FP8
trigger sees deepseek/zamba-class memory pressure too. The legacy
fixed-slot scheduling path (`_admit_legacy`/`_decode_legacy`) is
retired.

Recurrent families (ssm/hybrid) prefill with EXACT-length chunks (pad
tokens would be absorbed into the state) and disable prefix caching (a
cached KV prefix cannot stand in for slot-resident SSM state); batched
decode masks state writes on inactive rows.

Sliding-window archs (gemma3's 5:1 local:global layout) serve with one
block table PER WINDOW GROUP: local-layer blocks that slide fully out
of every future query's window are freed back to the pool mid-
generation (`BlockManager.slide_window`, invoked on every ensure) while
global-layer blocks stay pinned, so `free_block_frac` — and with it the
controller's memory-pressure FP8 trigger and the admission watermark —
reflects HONEST headroom instead of phantom pressure from dead
local-layer KV. Prefix matching is group-aware: global groups match the
full from-root chain, local groups only need (and only attach) the
blocks covering the resume position's lookback window.
`window_reclaim=False` keeps the group split but never slides — the
every-block-resident baseline the tests compare against.

Copy-on-write prefix caching (gqa/mla, on by default): at admission
the engine matches the longest cached full-block prefix of the request's
token stream (kvcache.py chain-hash index), attaches those blocks with
zero recompute, and starts chunked prefill at the matched offset —
always recomputing at least the final prompt token so the first-token
logit is produced. Before any chunk or decode write lands, shared
write-target blocks are COW-forked (`cow_for_write`) and their bytes
copied in the physical pool by one jitted block-copy; retire/preempt
decref blocks instead of freeing them, parking reusable prefixes in an
LRU pool that is reclaimed before preemption ever triggers. The paged
attention read path gathers keys through the block table in logical
order, so shared physical blocks are transparent to `paged_step` and the
planar decode kernel alike. `prefix_cache_stats()` reports hit-rate and
blocks saved.

N-gram speculative decoding (opt-in via `speculate=`): each decode row
may carry up to K drafted tokens proposed by a host-side suffix n-gram
match over the request's OWN token history (serving/speculate.py — no
draft model, no extra dispatch). The batched decode then runs as one
ragged C=K+1 `paged_step` chunk with per-column greedy argmax
(`sample_all=True`), and the longest accepted draft prefix is selected
ON DEVICE next to the fused sampling — the end-of-step sync pulls a
single packed `[ids | n_accepted]` array, so speculation adds zero host
syncs. Rejected draft positions are rolled back by pure block
bookkeeping (`BlockManager.truncate`: rejected writes only ever land in
COW-exclusive unregistered tail blocks, so garbage beyond the accepted
length is masked by kv_len and overwritten before it could become
valid), and the per-row draft length adapts to the measured acceptance
rate (`core.policy.AdaptiveKController` on the same `StepObservation`
stream the precision controller reads). Drafting is opportunistic and
NEVER preempts: draft extensions are clamped to `max_coverable` and
given back (truncate) if their COW fork cannot complete. Greedy outputs
are BIT-IDENTICAL with speculation on or off — drafts only decide how
many tokens one dispatch confirms, never which tokens. Recurrent
descriptors reject speculation (slot-resident SSM state cannot roll
back).

Greedy sampling; attention-family chunk lengths are bucketed and jit
caches key on (mode, bucket) with positions and slot index passed as
traced arguments, so distinct prompt lengths share one executable per
bucket (recurrent families compile per exact chunk length instead).

One-dispatch steps (host-orchestration overhead)
------------------------------------------------
The per-step host work is O(1) jitted dispatches and O(changed bytes)
host→device traffic, independent of how many sequences are prefilling
or decoding:

* ALL of a step's planned prompt chunks run as ONE batched ragged
  `paged_step` dispatch (attention-family descriptors): chunk rows are
  right-padded to a shared bucket, row count is bucketed to a power of
  two, and per-row `q_offset`/`kv_len`/`logit_position` carry the
  raggedness — executables key on (mode, rows-bucket, chunk-bucket),
  i.e. the total-chunk bucket. Disabled pad rows (kv_len=0) write to
  the trash block. Recurrent descriptors keep per-chunk dispatches
  (exact-length chunks + single-slot state routing).
* Block tables live on DEVICE (`BlockManager.device_tables()`): each
  dispatch reads the persistent mirror, and allocate/ensure/slide/COW
  mutations flush as one small jitted scatter instead of re-uploading
  the (G, n_slots, MB) array every step.
* Sampling is fused into the jitted step (`paged_step` returns argmax
  token ids), so decode pulls (B,) int32s back — not (B, vocab) floats
  — and the step's device results are synced ONCE at the end
  (`_finalize_step`); no `np.asarray` on live device values mid-step.
  A prefill that completes mid-step hands its on-device first token to
  the same step's decode through a tiny jitted overlay, never a sync.
* Caches are donated to every step dispatch, so XLA updates pools in
  place rather than copying them per step.

`stats` counts `prefill_dispatches`/`decode_dispatches`/
`aux_dispatches` and `h2d_bytes`; `benchmarks/bench_kernel_overhead.py`
turns them into the `engine_dispatch/*` rows the CI smoke asserts.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import json
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.compat import mesh_context
from repro.core.policy import (AdaptiveKController, DualPrecisionController,
                               RestorePolicy, SpeculationConfig,
                               StepObservation)
from repro.models import model as M
from repro.models.layers import Runtime
from repro.serving import shard as SHARD
from repro.serving.kvcache import (TRASH_BLOCK, BlockManager, HostPool,
                                   SlotManager)
from repro.serving.speculate import NgramProposer


@dataclasses.dataclass
class Request:
    request_id: str
    tokens: list[int]
    max_new: int
    arrival_s: float = 0.0
    # generation stops the step AFTER one of these ids is emitted (the
    # stop token itself is kept in `output`, EOS-style); an accepted
    # speculative run is cut at the first stop token mid-run
    stop_tokens: tuple[int, ...] = ()
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    finished_s: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    modes: list[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefill:
    """In-flight chunked prefill. seq_tokens is the full token stream to
    re-establish in the cache — prompt plus any output generated before a
    preemption (greedy decoding makes the recompute continuation exact)."""
    req: Request
    seq_tokens: list[int]
    done: int = 0


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


# placeholder for a token whose value still lives on device; patched by
# `_finalize_step`'s single end-of-step sync before anything reads it
_PENDING = -1


class Engine:
    def __init__(self, cfg: ArchConfig, serving_params, *, n_slots: int,
                 capacity: int, controller: DualPrecisionController | None = None,
                 forced_mode: str | None = None, backend: str = "ref",
                 attn_backend: str = "ref",
                 kv_planar: bool = False,
                 clock: Callable[[], float] = time.monotonic,
                 block_size: int = 16,
                 n_blocks: int | None = None, chunk_tokens: int = 256,
                 prefix_cache: bool = True, window_reclaim: bool = True,
                 debug_invariants: bool = False, mesh=None,
                 speculate: SpeculationConfig | bool | None = None,
                 host_offload: bool = True,
                 host_bytes: int | None = None,
                 restore_policy: RestorePolicy | None = None,
                 persist_dir: str | None = None,
                 fault_hook: Callable[["Engine"], None] | None = None):
        # mesh (launch.mesh.make_serving_mesh): drive an N-chip
        # tensor-parallel mesh as ONE logical device — weights and the
        # paged pool are committed to sharded layouts here (serving/
        # shard.py axis table) and every step stays a single pjit
        # dispatch whose partitioning GSPMD derives from them. None
        # preserves single-device serving byte-for-byte.
        self.cfg = cfg
        self.mesh = mesh
        self.params = serving_params if mesh is None \
            else SHARD.shard_serving_params(serving_params, cfg, mesh)
        self.controller = controller
        self.forced_mode = forced_mode
        self.clock = clock
        self.n_slots = n_slots
        self.capacity = capacity
        self.chunk_tokens = chunk_tokens
        # opt-in runtime sanitizer (Engine(debug_invariants=True) or
        # NFP_DEBUG=1): audit the BlockManager's refcount/free-list/
        # table-mirror invariants after every step instead of only where
        # a test remembers to call check_invariants()
        self.debug_invariants = debug_invariants \
            or os.environ.get("NFP_DEBUG") == "1"
        self.kv_planar = kv_planar and cfg.cache_kind == "gqa"
        # raises NotImplementedError for enc-dec — engine serves
        # decoder-only archs (enc-dec is covered by dry-run + benchmarks)
        self.desc = M.cache_descriptor(cfg, planar=self.kv_planar)
        # recurrent state can't be re-attached from cached KV blocks
        prefix_cache = prefix_cache and self.desc.prefix_cacheable
        # pad tokens are invisible to attention (causal mask + trash
        # block) but would be absorbed into SSM state: recurrent
        # families prefill with exact-length chunks instead of buckets
        self._pad_chunks = not self.desc.slot_planes
        # n-gram speculative decoding (module docstring): True picks the
        # default SpeculationConfig; rejected-draft rollback is pure
        # block bookkeeping, which slot-resident recurrent state cannot
        # provide — advancing an SSM recurrence is irreversible
        if speculate:
            if self.desc.slot_planes:
                raise ValueError(
                    "speculative decoding requires rolling back rejected "
                    "positions; slot-resident recurrent state (ssm/hybrid "
                    "descriptors) cannot be truncated")
            self._spec = speculate if isinstance(speculate, SpeculationConfig) \
                else SpeculationConfig()
            self._proposer = NgramProposer(self._spec)
            self._spec_k = AdaptiveKController(self._spec)
        else:
            self._spec = None
            self._proposer = None
            self._spec_k = None
        self._spec_cache: dict[tuple[str, int], Any] = {}
        self._last_spec = (0, 0)     # (drafted, accepted) of the last step
        self.queue: collections.deque[Request] = collections.deque()
        self.active: dict[int, Request] = {}
        self.prefilling: dict[int, _Prefill] = {}
        self.finished: list[Request] = []
        self.lens = np.zeros(n_slots, np.int32)
        self.stats = {"preemptions": 0, "chunks": 0, "chunk_tokens": 0,
                      "peak_block_util": 0.0, "window_reclaimed_blocks": 0,
                      # one-dispatch accounting (bench_kernel_overhead
                      # engine_dispatch/* rows): jitted calls per phase
                      # plus host->device bytes for step inputs (block
                      # tables are counted by BlockManager separately)
                      "prefill_dispatches": 0, "decode_dispatches": 0,
                      "aux_dispatches": 0, "h2d_bytes": 0,
                      # speculative decoding (spec_stats() / bench
                      # spec/* rows): decode_rows counts row-dispatches,
                      # decode_tokens the tokens they emitted — their
                      # ratio is tokens-accepted-per-dispatch (1.0
                      # without speculation, >1 iff drafts accepted)
                      "spec_dispatches": 0, "spec_drafted": 0,
                      "spec_accepted": 0, "decode_rows": 0,
                      "decode_tokens": 0,
                      # tiered KV (tiered_stats()): blocks/bytes spilled
                      # to the host tier, restored through the scatter
                      # path, lazily lo-plane-completed, admissions that
                      # fell back to recompute under the SLO guard, and
                      # the run() iteration-cap satellite counter — all
                      # host-side bookkeeping, so mesh-size-invariant
                      "spilled_blocks": 0, "spilled_bytes": 0,
                      "restored_blocks": 0, "restored_bytes": 0,
                      "lo_lazy_blocks": 0, "lo_lazy_bytes": 0,
                      "restore_fallbacks": 0, "iters_exhausted": 0,
                      # host-tier entries whose checksum failed at
                      # restore-drain time: the owning rows were
                      # preempted back to recompute (never served
                      # corrupt KV, never crashed)
                      "corrupt_fallbacks": 0}
        self._last_step_ms: float | None = None
        # failure-injection seam (serving/faults.py): called at the very
        # top of _step_inner, BEFORE any state mutates — an InjectedFault
        # raised here leaves the engine drainable. Stall faults add
        # virtual milliseconds to the step instead of raising:
        # inject_stall_ms is consumed into _last_step_ms (so the
        # dual-precision controller sees the slowdown) and surfaced to
        # the router as last_stall_ms.
        self.fault_hook = fault_hook
        self.inject_stall_ms = 0.0
        self.last_stall_ms = 0.0
        self.last_mode: str | None = None
        # attn_backend="pallas" serves planar GQA decode through the
        # block-table scalar-prefetch kernel (layers.attention "paged");
        # anything it cannot serve falls back to the ref gather path.
        # act_quant="per_token": fp8 generation must be batch-invariant
        # under continuous batching (and speculative verification chunks)
        # — per-tensor dynamic scales would couple co-batched tokens'
        # rounding (Runtime docstring).
        self._rts = {m: Runtime(mode=m, backend=backend, dtype=jnp.float32,
                                act_quant="per_token",
                                attn_backend=None if attn_backend == "ref"
                                else attn_backend, mesh=mesh)
                     for m in ("fp16", "fp8")}
        self.block_size = block_size
        mbs = -(-capacity // block_size)
        # per-layer-group window metadata: sliding-window archs (gemma3)
        # keep one block table per group — each group allocates from its
        # own id space over the same pool array (a layer only touches
        # its group's rows of a block), so local-layer blocks can be
        # slide-freed mid-generation while global-layer blocks stay
        # pinned, at zero extra pool bytes; window_reclaim=False keeps
        # the group split but never slides (the
        # every-block-resident-forever baseline)
        gw = self.desc.group_windows
        if not window_reclaim:
            gw = (None,) * len(gw)
        if n_blocks is None:
            n_blocks = n_slots * mbs         # dense-equivalent pool by default
        # tiered KV (kvcache.py HostPool): spill LRU-evicted prefix
        # blocks to a host pool instead of discarding them, restore
        # matched blocks through the scatter-upload path under the
        # RestorePolicy SLO guard, and (persist_dir) serialize index +
        # host pool across engine restarts. Only prefix-cacheable paged
        # families participate — recurrent state cannot be re-attached.
        self._host_tier = bool(host_offload and prefix_cache
                               and self.desc.paged
                               and not self.desc.slot_planes)
        self._restore_policy = restore_policy or RestorePolicy()
        self.persist_dir = persist_dir
        self.blocks = BlockManager(n_slots, block_size, n_blocks, mbs,
                                   prefix_cache=prefix_cache,
                                   group_windows=gw,
                                   mirror_sharding=None if mesh is None
                                   else SHARD.replicated(mesh),
                                   host_pool=HostPool(host_bytes)
                                   if self._host_tier else None)
        # slot-resident state side (hybrid/ssm descriptors): SlotManager
        # tracks per-slot occupancy in lockstep with the block tables
        self.slot_state = SlotManager(n_slots, capacity) \
            if self.desc.slot_planes else None
        self.caches = M.init_paged_cache(
            cfg, self.blocks.n_total_blocks, block_size, n_slots=n_slots,
            planar=self.kv_planar, mesh=mesh)
        # the step entry point: identical call signature either way, so
        # the dispatch sites below never branch on the mesh. Sharded
        # mode routes through serving/shard.sharded_paged_step (a
        # repro-lint hot root), which pins the tiny control operands
        # replicated and leaves pool/weight partitioning to GSPMD.
        self._paged_step = M.paged_step if mesh is None \
            else functools.partial(SHARD.sharded_paged_step, mesh)
        # one compile per window group: src/dst are traced scalars into
        # the block axis; donating the cache lets XLA update the one
        # block in place instead of materializing a whole-pool copy per
        # COW fork. Only paged-plane subtrees are touched —
        # slot-resident state ("ssm") has a slot axis, not a block
        # axis. With per-group block id spaces a fork must copy ONLY
        # the group's layer rows: the same physical id may be live in
        # the other group with unrelated content.
        def _make_copy(layers):
            if layers is None:               # single group: all layers
                cp = lambda a, s, d: a.at[:, d].set(a[:, s])
            else:
                li = jnp.asarray(layers, jnp.int32)
                cp = lambda a, s, d: a.at[li, d].set(a[li, s])
            return jax.jit(
                lambda c, s, d: {
                    k: (jax.tree.map(lambda a: cp(a, s, d), sub)
                        if k in ("attn", "shared") else sub)
                    for k, sub in c.items()},
                donate_argnums=(0,))
        if self.desc.groups:
            self._copy_block = {gi: _make_copy(g.layers)
                                for gi, g in enumerate(self.desc.groups)}
        else:
            self._copy_block = {0: _make_copy(None)}
        # tiered-KV executables: per window group, ONE jitted pool
        # gather (spill capture: d2h of K evicted blocks' plane bytes)
        # and ONE jitted pool scatter per plane set (restore upload —
        # the same dirty-scatter discipline the block tables use). Block
        # counts are padded to a power of two (gather pads repeat the
        # last id; scatter pads aim at the trash block — both
        # idempotent), so a handful of executables serve every drain.
        # Planar (NestedKV) pools split the plane set: fp8 hi planes
        # upload eagerly at restore, lo planes lazily on the first
        # FP16-mode touch — half the restore h2d while serving fp8.
        if self._host_tier:
            pool_key = "shared" if self.desc.kind == "hybrid" else "attn"
            names = tuple(p.name for p in self.desc.planes)
            self._lo_planes = tuple(n for n in names if n.endswith("_lo")) \
                if self.kv_planar else ()
            self._hi_planes = tuple(n for n in names
                                    if n not in self._lo_planes)

            def _make_tier(layers):
                if layers is None:
                    sel = lambda a, ids: a[:, ids]
                    put = lambda a, ids, v: a.at[:, ids].set(v)
                else:
                    li = jnp.asarray(layers, jnp.int32)
                    sel = lambda a, ids: a[li[:, None], ids[None, :]]
                    put = lambda a, ids, v: \
                        a.at[li[:, None], ids[None, :]].set(v)
                gather = jax.jit(lambda c, ids: {
                    p: sel(a, ids) for p, a in c[pool_key].items()})

                def make_scatter(plane_names):
                    pn = tuple(plane_names)

                    def f(c, ids, vals):
                        sub = dict(c[pool_key])
                        for p in pn:
                            sub[p] = put(sub[p], ids, vals[p])
                        out = dict(c)
                        out[pool_key] = sub
                        return out
                    return jax.jit(f, donate_argnums=(0,))
                return gather, make_scatter
            glayers = [g.layers for g in self.desc.groups] \
                if self.desc.groups else [None]
            self._spill_gather, self._scatter_hi, self._scatter_lo = {}, {}, {}
            self._eager_block_bytes, self._lo_block_bytes = {}, {}
            by_name = {p.name: p for p in self.desc.planes}
            for gi, lys in enumerate(glayers):
                gather, make_scatter = _make_tier(lys)
                self._spill_gather[gi] = gather
                self._scatter_hi[gi] = make_scatter(self._hi_planes)
                if self._lo_planes:
                    self._scatter_lo[gi] = make_scatter(self._lo_planes)
                nl = len(lys) if lys is not None else self.desc.planes[0].n_layers

                def pbytes(pl):
                    return sum(int(nl * block_size
                                   * np.prod(by_name[p].token_shape,
                                             dtype=np.int64)
                                   * np.dtype(by_name[p].dtype).itemsize)
                               for p in pl)
                self._eager_block_bytes[gi] = pbytes(self._hi_planes)
                self._lo_block_bytes[gi] = pbytes(self._lo_planes)
        if self.slot_state is not None:
            # zero one slot's recurrent state at (re-)admission
            self._zero_slot = jax.jit(
                lambda c, i: {
                    k: (jax.tree.map(lambda a: a.at[:, i].set(0), sub)
                        if k == "ssm" else sub)
                    for k, sub in c.items()},
                donate_argnums=(0,))
        # batched decode: greedy sampling fused into the step (returns
        # (n_slots,) int32 ids, not (B, vocab) logits); caches donated so
        # pools update in place
        self._decode = {
            m: jax.jit(lambda p, c, t, tab, qo, kvl, _m=m:
                       self._paged_step(
                self._rts[_m], p, cfg, t, c, tab, q_offset=qo,
                kv_len=kvl, block_size=block_size), donate_argnums=(1,))
            for m in ("fp16", "fp8")}
        self._chunk_cache: dict[tuple[str, int], Any] = {}
        self._fused_cache: dict[tuple[str, int, int], Any] = {}
        # scatter a completing prefill's on-device first token into the
        # same step's decode inputs (no host sync on the seam)
        self._overlay = jax.jit(lambda t, s, ids, r: t.at[s, 0].set(ids[r]))
        self.iteration = 0
        if self._host_tier and persist_dir:
            self._load_prefix_store(persist_dir)

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue one request, validating it up front: a malformed
        request must fail HERE with a clear error, not steps later as a
        scheduling failure deep inside `_plan_chunks`/`try_allocate`."""
        if not req.tokens:
            raise ValueError(f"request {req.request_id}: empty prompt")
        if req.max_new <= 0:
            raise ValueError(
                f"request {req.request_id}: max_new={req.max_new} must be "
                f"positive — a request that may emit nothing can never "
                f"retire")
        total = len(req.tokens) + req.max_new
        if total > self.capacity:
            raise ValueError(
                f"request {req.request_id}: prompt ({len(req.tokens)}) + "
                f"max_new ({req.max_new}) = {total} exceeds per-sequence "
                f"capacity {self.capacity}")
        bm = self.blocks
        if any(bm._group_need(total, w) > bm.n_blocks
               for w in bm.group_windows):
            raise ValueError(
                f"request {req.request_id}: needs more KV blocks than a "
                f"whole group pool holds ({bm.n_blocks}) — the pool can "
                f"never cover it")
        self.queue.append(req)

    def drain_requests(self) -> list[Request]:
        """Evacuate every in-flight request (admission order, then
        queue order), releasing all KV blocks and slots — the router's
        failover export. Outputs are sanitized (a trailing `_PENDING`
        placeholder from an interrupted step is dropped along with its
        timing/mode entries) so a survivor can resubmit each request
        as-is: re-prefilling prompt + emitted-so-far continues greedy
        generation exactly (`_plan_chunks` replay invariant)."""
        order = sorted(set(self.active) | set(self.prefilling),
                       key=lambda i: self.blocks.seqs[i].admitted)
        out: list[Request] = []
        for idx in order:
            if idx in self.active:
                out.append(self.active.pop(idx))
            else:
                out.append(self.prefilling.pop(idx).req)
            self.blocks.release(idx)
            if self.slot_state is not None:
                self.slot_state.release(idx)
            self.lens[idx] = 0
        out.extend(self.queue)
        self.queue.clear()
        for req in out:
            while req.output and req.output[-1] == _PENDING:
                req.output.pop()
                if req.token_times:
                    req.token_times.pop()
                if req.modes:
                    req.modes.pop()
            if not req.output:
                req.first_token_s = None     # the dropped placeholder was
                                             # the "first token"
        return out

    def run(self, max_iters: int = 10_000,
            allow_partial: bool = False) -> list[Request]:
        """Step until every submitted request finishes. Hitting
        `max_iters` with work still queued/active is an ERROR unless
        `allow_partial=True` — a silently-truncated run used to let
        benches report a partially-served trace as complete. Either way
        `stats["iters_exhausted"]` records how many requests were left
        unserved when the cap hit."""
        while (self.queue or self.active or self.prefilling) \
                and self.iteration < max_iters:
            self.step()
        leftover = len(self.queue) + len(self.active) + len(self.prefilling)
        if leftover:
            self.stats["iters_exhausted"] = leftover
            if not allow_partial:
                raise RuntimeError(
                    f"run(max_iters={max_iters}) exhausted its iteration "
                    f"cap with {leftover} requests unfinished; pass "
                    f"allow_partial=True to accept a partially-served "
                    f"trace")
        return self.finished

    def block_utilization(self) -> float:
        return self.blocks.utilization()

    def prefix_cache_stats(self) -> dict:
        """Prefix-cache effectiveness: hit rate over prompt tokens looked
        up at admission, blocks saved by sharing, COW forks, LRU churn
        (all-zero for recurrent descriptors, which disable the cache)."""
        ps = self.blocks.prefix_stats
        denom = ps["lookup_tokens"]
        return {"hit_rate": ps["hit_tokens"] / denom if denom else 0.0,
                "hit_tokens": ps["hit_tokens"],
                "blocks_saved": ps["blocks_shared"],
                "cached_blocks": self.blocks.n_cached_blocks(),
                "cow_forks": ps["cow_forks"],
                "evictions": ps["evictions"]}

    def spec_stats(self) -> dict:
        """Speculation effectiveness. `tokens_accepted_per_dispatch` is
        the per-row mean tokens confirmed by one decode dispatch: exactly
        1.0 without speculation, > 1 iff drafts were accepted. All ratios
        guard their denominators — a trace that never decoded (or never
        drafted) reports 0.0, it does not raise."""
        s = self.stats
        return {"enabled": self._spec is not None,
                "spec_dispatches": s["spec_dispatches"],
                "drafted": s["spec_drafted"],
                "accepted": s["spec_accepted"],
                "acceptance_rate": s["spec_accepted"] / s["spec_drafted"]
                if s["spec_drafted"] else 0.0,
                "tokens_accepted_per_dispatch":
                s["decode_tokens"] / s["decode_rows"]
                if s["decode_rows"] else 0.0,
                "k": self._spec_k.k if self._spec_k else 0}

    @property
    def restore_policy(self) -> RestorePolicy:
        """The live SLO guard on the tiered-KV restore path — swappable
        at runtime (the router's DegradePolicy tightens it on survivors
        while the fleet runs short-handed, and restores it after)."""
        return self._restore_policy

    @restore_policy.setter
    def restore_policy(self, policy: RestorePolicy) -> None:
        self._restore_policy = policy

    # -- tiered KV: spill / restore / persist ---------------------------------
    def tiered_stats(self) -> dict:
        """Host-tier effectiveness: blocks spilled (d2h captures),
        restored (scatter uploads), lazily lo-completed, admissions the
        SLO guard bounced to recompute, and current tier occupancy."""
        s, bm = self.stats, self.blocks
        host = bm.host
        return {"enabled": self._host_tier,
                "host_blocks": len(host) if host is not None else 0,
                "host_bytes": host.bytes if host is not None else 0,
                "spilled_blocks": s["spilled_blocks"],
                "spilled_bytes": s["spilled_bytes"],
                "restored_blocks": s["restored_blocks"],
                "restored_bytes": s["restored_bytes"],
                "lo_lazy_blocks": s["lo_lazy_blocks"],
                "lo_lazy_bytes": s["lo_lazy_bytes"],
                "restore_fallbacks": s["restore_fallbacks"],
                "host_hit_blocks": bm.prefix_stats["host_hit_blocks"],
                "queued_restores": len(bm.restore_jobs)}

    def _tier_dev(self, a: np.ndarray):
        """Device placement for tiny host-built spill/restore operands
        (block ids, stacked plane values): replicated under a mesh so
        GSPMD never tries to partition control data."""
        if self.mesh is None:
            return jnp.asarray(a)
        return SHARD.put_replicated(self.mesh, a)

    def _capture_blocks(self, jobs: list[tuple[int, int, int]]) -> None:
        """Copy (group, block, hash) pool bytes into the host tier: one
        jitted per-group gather (ids padded to a power of two by
        repeating the last id — idempotent), then a single batched d2h
        pull per group. Used by `_flush_spills` (eviction/preemption
        spills) and `save_prefix_store` (non-evicting index mirror)."""
        bm = self.blocks
        by_g: dict[int, list[tuple[int, int]]] = {}
        for g, b, h in jobs:
            by_g.setdefault(g, []).append((b, h))
        for g, items in sorted(by_g.items()):
            kb = _bucket(len(items), 1)
            ids = np.full(kb, items[-1][0], np.int32)
            for i, (b, _h) in enumerate(items):
                ids[i] = b
            out = self._spill_gather[g](self.caches, self._tier_dev(ids))
            # nfp: ignore[NFP001] tiered-KV spill capture: batched d2h of evicted cold blocks, an aux transfer that never sits on the step's argmax sync
            planes = jax.device_get(out)
            for i, (_b, h) in enumerate(items):
                entry = {p: np.ascontiguousarray(a[:, i])
                         for p, a in planes.items()}
                bm.store_spill(g, h, entry)
                self.stats["spilled_blocks"] += 1
                self.stats["spilled_bytes"] += sum(
                    a.nbytes for a in entry.values())
            self.stats["aux_dispatches"] += 1

    def _flush_spills(self) -> None:
        """Capture every queued evicted-block spill to the host tier.
        MUST run before any cache-writing dispatch: the evicted block
        ids are already reallocated, so their bytes are intact only
        until the next write lands. No-op when nothing is queued."""
        if not self._host_tier:
            return
        jobs = self.blocks.take_spills()
        if jobs:
            self._capture_blocks(jobs)

    def _tier_upload(self, g: int, items: list[tuple[int, int]],
                     names: tuple[str, ...]) -> int:
        """Scatter host-tier bytes for `names` planes of [(block, hash)]
        `items` into group g's pool rows (one jitted donated scatter —
        the same upload path the device table mirror uses). Pad slots
        aim at the trash block. Returns bytes shipped."""
        bm = self.blocks
        kb = _bucket(len(items), 1)
        ids = np.full(kb, TRASH_BLOCK, np.int32)
        vals: dict[str, np.ndarray] = {}
        nbytes = 0
        for i, (b, h) in enumerate(items):
            ids[i] = b
            entry = bm.host.get((g, h))
            for p in names:
                a = entry[p]
                if p not in vals:
                    vals[p] = np.zeros((a.shape[0], kb) + a.shape[1:],
                                       a.dtype)
                vals[p][:, i] = a
                nbytes += a.nbytes
        self.caches = (self._scatter_hi if names == self._hi_planes
                       else self._scatter_lo)[g](
            self.caches, self._tier_dev(ids),
            {p: self._tier_dev(v) for p, v in vals.items()})
        self.stats["aux_dispatches"] += 1
        return nbytes

    def _restore_queued_bytes(self) -> int:
        """Eager (hi-plane) bytes waiting in the restore queue — the
        backlog the RestorePolicy's admission gate reads."""
        return sum(self._eager_block_bytes[g]
                   for g, _b, _h, _t in self.blocks.restore_jobs)

    def _host_admit(self) -> bool:
        """May this admission match host-tier blocks? The SLO guard
        bounces the match to plain recompute when the restore backlog
        would blow TPOT (`stats["restore_fallbacks"]`)."""
        if not self._host_tier:
            return False
        bm = self.blocks
        if not (len(bm.host) or bm._spill_pending):
            return True                      # nothing to restore anyway
        if self._restore_policy.admit(self._restore_queued_bytes()):
            return True
        self.stats["restore_fallbacks"] += 1
        return False

    def _drain_restores(self) -> None:
        """Upload queued host-tier restores at the top of the step,
        bounded by the RestorePolicy's per-step byte grant (always at
        least one block, so gated rows make progress — the guard shapes
        latency, it cannot deadlock). Spill captures run first: a
        restore may target an entry whose bytes are still queued for
        capture."""
        bm = self.blocks
        if not self._host_tier or not bm.restore_jobs:
            return
        self._flush_spills()
        budget = self._restore_policy.grant(self._restore_queued_bytes())
        taken: dict[int, list[tuple[int, int]]] = {}
        spent = 0
        while bm.restore_jobs:
            g, b, h, t = bm.restore_jobs[0]
            if not bm.claim_restore(g, b, h, t):
                bm.restore_jobs.popleft()    # voided by release/preempt
                continue
            if not bm.host_ok(g, h):
                # checksum mismatch: never scatter these bytes — preempt
                # the owners back to recompute and drop the entry
                bm.restore_jobs.popleft()
                self._corrupt_fallback(g, b, h)
                continue
            cost = self._eager_block_bytes[g]
            if spent and spent + cost > budget:
                break
            bm.restore_jobs.popleft()
            taken.setdefault(g, []).append((b, h))
            spent += cost
        lazy = bool(self._lo_planes)
        for g, items in sorted(taken.items()):
            nbytes = self._tier_upload(g, items, self._hi_planes)
            for b, h in items:
                bm.finish_restore(g, b, h, lo_pending=lazy)
            self.stats["restored_blocks"] += len(items)
            self.stats["restored_bytes"] += nbytes

    def _corrupt_fallback(self, g: int, b: int, h: int) -> None:
        """A claimed restore's host bytes failed their checksum: preempt
        every row holding the destination block (requeued rows re-prefill
        prompt + emitted-so-far — the replay invariant makes the
        recompute continuation exact), then drop the poisoned entry so
        future matches recompute too. Counted, never raised, and never
        a wrong token: the garbage bytes are never scattered."""
        bm = self.blocks
        for idx in bm.rows_holding(g, b):
            self._preempt(idx)
        if (g, h) in bm.host and not bm.host.pinned((g, h)):
            bm.host.discard((g, h))
        self.stats["corrupt_fallbacks"] += 1

    def _sweep_corrupt_lo(self) -> None:
        """Integrity-sweep deferred lo-plane sources at the top of the
        step — BEFORE planning, where preemption is safe. A corrupt
        entry's block is purged (its device hi planes may be fine, but
        fp16 would join garbage lo bytes), its owner rows recompute, and
        the entry is dropped; the mid-step lo-upload sites may then
        trust whatever they drain."""
        bm = self.blocks
        if not (self._host_tier and self._lo_planes and bm._lo_pending):
            return
        for (g, b), h in list(bm._lo_pending.items()):
            if bm.host.verify((g, h)):
                continue
            del bm._lo_pending[(g, b)]
            bm.host.unpin((g, h))
            for idx in bm.rows_holding(g, b):
                self._preempt(idx)
            bm.purge_block(g, b)
            if not bm.host.pinned((g, h)):
                bm.host.discard((g, h))
            self.stats["corrupt_fallbacks"] += 1

    def _upload_lo(self, triples: list[tuple[int, int, int]]) -> None:
        """Complete deferred lo planes for (group, block, hash) triples
        (host-entry pins transfer here and are released after the
        upload)."""
        if not triples:
            return
        bm = self.blocks
        self._flush_spills()
        by_g: dict[int, list[tuple[int, int]]] = {}
        for g, b, h in triples:
            by_g.setdefault(g, []).append((b, h))
        for g, items in sorted(by_g.items()):
            nbytes = self._tier_upload(g, items, self._lo_planes)
            for _b, h in items:
                bm.host.unpin((g, h))
            self.stats["lo_lazy_blocks"] += len(items)
            self.stats["lo_lazy_bytes"] += nbytes

    def _ensure_lo(self, mode: str) -> None:
        """FP16 joins hi+lo planes everywhere, so the first FP16-mode
        step after a planar restore must land every deferred lo plane
        before it dispatches."""
        if mode == "fp16" and self._host_tier and self._lo_planes:
            self._upload_lo(self.blocks.take_lo_pending())

    def _store_meta(self) -> dict:
        """Layout fingerprint of the persisted prefix store: a store is
        only loadable into an engine whose chain hashes AND pool plane
        shapes mean the same thing."""
        return {"version": 1, "arch_id": self.cfg.arch_id,
                "kind": self.desc.kind, "planar": bool(self.kv_planar),
                "block_size": self.block_size,
                "group_windows": [w if w is None else int(w)
                                  for w in self.blocks.group_windows],
                "planes": {p.name: [list(p.token_shape), p.dtype]
                           for p in self.desc.planes}}

    def save_prefix_store(self, path: str | None = None) -> int:
        """Mirror the ENTIRE prefix index into the host tier (a
        non-evicting batched capture) and serialize it — chain-hash keys
        plus block bytes — to `path` (default `persist_dir`). Because
        chain hashes are stable blake2b content digests, a fresh
        `Engine(persist_dir=...)` in another process re-admits these
        prefixes without recomputing them. Returns entries written."""
        path = path or self.persist_dir
        if not self._host_tier or not path:
            raise ValueError("save_prefix_store needs host_offload and a "
                             "persist_dir/path")
        with (contextlib.nullcontext() if self.mesh is None
              else mesh_context(self.mesh)):
            self._flush_spills()
            self._capture_blocks(self.blocks.mirror_jobs())
        os.makedirs(path, exist_ok=True)
        arrs = {f"{g}|{h}|{p}": a
                for (g, h), planes in self.blocks.host.entries.items()
                for p, a in planes.items()}
        np.savez(os.path.join(path, "prefix_store.npz"), **arrs)
        with open(os.path.join(path, "prefix_store.json"), "w") as f:
            json.dump(self._store_meta(), f)
        return len(self.blocks.host)

    def _load_prefix_store(self, path: str) -> int:
        """Load a persisted prefix store into the host tier (engine
        construction). A missing store or a layout-fingerprint mismatch
        loads nothing — stale bytes must never be joined with a
        different block size, plane layout, or window split."""
        meta_p = os.path.join(path, "prefix_store.json")
        npz_p = os.path.join(path, "prefix_store.npz")
        if not (os.path.exists(meta_p) and os.path.exists(npz_p)):
            return 0
        with open(meta_p) as f:
            if json.load(f) != self._store_meta():
                return 0
        entries: dict[tuple[int, int], dict[str, np.ndarray]] = {}
        with np.load(npz_p) as data:
            for key in data.files:
                g, h, p = key.split("|", 2)
                entries.setdefault((int(g), int(h)), {})[p] = data[key]
        for key, planes in entries.items():
            self.blocks.host.put(key, planes, loaded=True)
        return len(entries)

    # -- mode selection -------------------------------------------------------
    def _mode(self, decode_tokens: int, prefill_tokens: int,
              free_block_frac: float | None = None) -> str:
        if self.forced_mode:
            return self.forced_mode
        if self.controller is None:
            return "fp16"
        obs = StepObservation(batch_tokens=max(decode_tokens, 1),
                              queue_depth=len(self.queue),
                              measured_step_ms=self._last_step_ms,
                              prefill_tokens=prefill_tokens,
                              free_block_frac=free_block_frac)
        return self.controller.decide(obs)

    # -- step -----------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: O(1) jitted dispatches regardless of how
        many sequences are prefilling or decoding (attention families —
        recurrent descriptors dispatch per chunk), with the step's device
        results synced to host exactly once at the end.

        Under a serving mesh the dispatch/h2d counters in `stats` keep
        counting LOGICAL steps: every jitted call below is one pjit
        program spanning all shards, so `prefill_dispatches` et al. and
        `h2d_bytes` are mesh-size-invariant (asserted by the dispatch
        tests) — replication fan-out is XLA's job, not a per-shard loop
        here."""
        with (contextlib.nullcontext() if self.mesh is None
              else mesh_context(self.mesh)):
            # the ambient mesh lets shard_hint constraints inside the
            # model stack (mla absorbed-q pinning et al.) take effect;
            # all committed-operand partitioning works without it
            self._step_inner()

    def _step_inner(self) -> None:
        if self.fault_hook is not None:
            # containment point: nothing has mutated yet, so a raise
            # here (InjectedFault or a real defect surfaced by the
            # harness) leaves the engine fully drainable
            self.fault_hook(self)
        self.iteration += 1
        t0 = self.clock()
        # land queued host-tier restores first (SLO-bounded): rows whose
        # blocks finish restoring here become schedulable this very step
        self._sweep_corrupt_lo()
        self._drain_restores()
        plan = self._plan_chunks()
        mode = self._mode(len(self.active),
                          sum(take for _, _, take in plan),
                          free_block_frac=self.blocks.free_block_frac())
        # planar pools restore hi planes eagerly, lo lazily: the first
        # FP16-mode step joins hi+lo, so deferred lo bytes land NOW
        self._ensure_lo(mode)
        # pending: (req, output index, device ids, row, slot) patched —
        # and EOS-checked — at the end-of-step sync; fresh: (slot,
        # device ids, row) prefills that completed this step and decode
        # below with a device-held token
        pending: list[tuple[Request, int, Any, int, int]] = []
        fresh: list[tuple[int, Any, int]] = []
        chunk_ids = self._run_chunks(mode, plan, pending, fresh)
        decode_ids, drafts = self._decode_paged(mode, chunk_ids, fresh)
        self._finalize_step(mode, pending, decode_ids, drafts)
        self._sample_peak()
        # wall time of this step feeds the controller's p90 tracker on the
        # NEXT decision (measured-latency fallback to FP8, paper §3.2);
        # injected stalls ride on top so the controller reacts to them
        self.last_mode = mode
        self.last_stall_ms, self.inject_stall_ms = self.inject_stall_ms, 0.0
        self._last_step_ms = (self.clock() - t0) * 1e3 + self.last_stall_ms
        if self.debug_invariants:
            # outside the measured step window, so the controller's p90
            # and the bench rows stay honest under NFP_DEBUG=1
            self.blocks.check_invariants()

    # =========================================================================
    # paged path: chunked prefill + block-table decode
    # =========================================================================
    def _ensure_take(self, idx: int, start: int, want: int) -> int:
        """Largest chunk <= want coverable by already-owned + free blocks
        across every window group (sliding dead local blocks back into
        the pool first)."""
        bm = self.blocks
        take = bm.max_coverable(idx, start, want)
        if take <= 0 or not bm.ensure(idx, start + take):
            return 0
        return take

    def _plan_chunks(self) -> list[tuple[int, int, int]]:
        """Schedule this step's prefill work: continue in-flight prefills
        (oldest first), then admit queued requests while the chunk-token
        budget, a slot, and enough free blocks for their WHOLE prompt are
        available (the admission watermark — decode growth may still
        preempt, but admissions never immediately thrash)."""
        plan: list[tuple[int, int, int]] = []
        budget = self.chunk_tokens
        order = sorted(self.prefilling,
                       key=lambda i: self.blocks.seqs[i].admitted)
        for idx in order:
            if budget <= 0:
                break
            if self.blocks.row_unrestored(idx):
                continue    # host-tier restore in flight: reads would
                            # see garbage; _drain_restores ungates it
            st = self.prefilling[idx]
            want = min(len(st.seq_tokens) - st.done, budget)
            take = self._ensure_take(idx, st.done, want)
            if take > 0:
                plan.append((idx, st.done, take))
                budget -= take
        while budget > 0 and self.queue:
            req = self.queue[0]
            seq_tokens = req.tokens + req.output
            idx = self.blocks.try_allocate(
                req.request_id, len(seq_tokens),
                req.max_new - len(req.output),
                cached_blocks=self.blocks.prefix_admit_discount(seq_tokens))
            if idx is None:
                break
            self.queue.popleft()
            if self.slot_state is not None:
                # slot-resident state side: claim the same slot index and
                # zero its recurrent state (recompute after preemption
                # must restart the recurrence from scratch)
                self.slot_state.claim(idx, req.request_id, len(seq_tokens),
                                      req.max_new - len(req.output))
                self.caches = self._zero_slot(self.caches, jnp.int32(idx))
                self.stats["aux_dispatches"] += 1
            # longest cached full-block prefix is shared (incref, zero
            # recompute); prefill starts at the matched offset but always
            # recomputes >= 1 token so the first-token logit is produced
            # (cow_for_write forks the tail block if that write would
            # land in a shared one)
            matched = self.blocks.attach_prefix(
                idx, seq_tokens, allow_host=self._host_admit())
            start = min(matched, len(seq_tokens) - 1)
            self.blocks.set_length(idx, start)
            st = _Prefill(req, seq_tokens, done=start)
            self.prefilling[idx] = st
            if self.blocks.row_unrestored(idx):
                continue    # attached host-tier blocks: the first chunk
                            # waits for their restore uploads to land
            take = self._ensure_take(
                idx, start, min(len(seq_tokens) - start, budget))
            if take > 0:
                plan.append((idx, start, take))
                budget -= take
        return plan

    def _h2d(self, a: np.ndarray):
        """Host->device upload with byte accounting (engine_dispatch/*
        bench rows report bytes per step/token)."""
        self.stats["h2d_bytes"] += a.nbytes
        return jnp.asarray(a)

    def _chunk_fn(self, mode: str, bucket: int):
        """Single-row prefill chunk executable (recurrent descriptors —
        attention families batch through `_fused_fn` instead). The
        traced `slot` routes the chunk's state read/write to one state
        row; the row's block table is sliced from the device-resident
        (G, n_slots, MB) array by a traced slot index, so jit caches
        still key on (mode, bucket) alone."""
        key = (mode, bucket)
        if key not in self._chunk_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size
            slotted = self.slot_state is not None

            def fn(p, caches, tokens, tables, row, q_offset, kv_len,
                   logit_pos, slot):
                table = jax.lax.dynamic_slice_in_dim(tables, row, 1, axis=1)
                return self._paged_step(rt, p, cfg, tokens, caches, table,
                                    q_offset=q_offset, kv_len=kv_len,
                                    block_size=bs, logit_position=logit_pos,
                                    slot=slot if slotted else None)
            self._chunk_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._chunk_cache[key]

    def _fused_fn(self, mode: str, rows_bucket: int, chunk_bucket: int):
        """Batched ragged prefill executable: every planned chunk of a
        step runs as one dispatch. Rows are independent single-sequence
        chunks (per-row q_offset/kv_len/logit_position carry the
        raggedness; kv_len=0 disables pad rows); each row's block table
        is gathered from the device-resident array by a traced slot
        vector, so the jit cache keys on (mode, rows-bucket,
        chunk-bucket) — the total-chunk bucket — alone."""
        key = (mode, rows_bucket, chunk_bucket)
        if key not in self._fused_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size

            def fn(p, caches, tokens, tables, rows, q_offset, kv_len,
                   logit_pos):
                tab = jnp.take(tables, rows, axis=1)     # (G, R, MB)
                return self._paged_step(rt, p, cfg, tokens, caches, tab,
                                    q_offset=q_offset, kv_len=kv_len,
                                    block_size=bs, logit_position=logit_pos)
            self._fused_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._fused_cache[key]

    def _spec_fn(self, mode: str, cb: int):
        """Speculative verification executable: the batched decode as a
        ragged C=cb chunk (column 0 the pending token, columns 1..K the
        drafts, pad columns masked by per-row kv_len), per-column greedy
        argmax (`sample_all`), and the longest-accepted-prefix selection
        FUSED next to it — draft j survives iff it matches the argmax
        after position j-1 AND every earlier draft survived (the
        cumprod). Returns ONE packed (B, cb+1) int32 array `[ids |
        n_accepted]` so the end-of-step sync stays a single pull; the jit
        cache keys on (mode, draft-bucket) via `_bucket`, exactly like
        the prefill executables."""
        key = (mode, cb)
        if key not in self._spec_cache:
            rt, cfg, bs = self._rts[mode], self.cfg, self.block_size

            def fn(p, caches, toks, tables, qo, kvl, dlen):
                ids, new_caches = self._paged_step(
                    rt, p, cfg, toks, caches, tables, q_offset=qo,
                    kv_len=kvl, block_size=bs, sample_all=True)
                # ids[:, j] = greedy successor of position qo+j; draft
                # toks[:, j] (the input at position qo+j) is confirmed
                # iff it equals ids[:, j-1]; dlen masks pad columns
                m = (ids[:, :-1] == toks[:, 1:]) \
                    & (jnp.arange(1, cb)[None, :] <= dlen[:, None])
                n_acc = jnp.cumprod(m.astype(jnp.int32), axis=1).sum(axis=1)
                return jnp.concatenate(
                    [ids, n_acc[:, None]], axis=1), new_caches
            self._spec_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._spec_cache[key]

    def _apply_cow(self, triples: list[tuple[int, int, int]]) -> None:
        """Materialize COW forks: copy each forked block's bytes — the
        owning group's layer rows only — in the physical pool (one
        jitted scatter per group, src/dst traced)."""
        if self._host_tier and triples:
            # copies are cache writes: capture queued spills first, and
            # complete any fork SOURCE's deferred lo planes — the copy
            # clones all planes, so a lo-pending src would hand the dst
            # stale lo bytes with no lo_pending record of its own
            self._flush_spills()
            self._upload_lo(self.blocks.take_lo_pending_for(
                [(g, src) for g, src, _dst in triples]))
        for g, src, dst in triples:
            self.caches = self._copy_block[g](
                self.caches, jnp.int32(src), jnp.int32(dst))
            self.stats["aux_dispatches"] += 1

    def _cow_or_preempt(self, idx: int, start: int, end: int) -> bool:
        """Fork shared blocks covering the write range [start, end);
        preempt youngest sequences while the pool is too exhausted to
        fork. False when `idx` itself got preempted."""
        pairs = self.blocks.cow_for_write(idx, start, end)
        while pairs is None:
            victim = self.blocks.youngest()
            if victim is None:
                raise RuntimeError("KV pool exhausted with nothing "
                                   "preemptible")
            self._preempt(victim)
            if idx not in self.prefilling and idx not in self.active:
                return False                 # preempted ourselves
            pairs = self.blocks.cow_for_write(idx, start, end)
        self._apply_cow(pairs)
        return True

    def _sample_peak(self) -> None:
        self.stats["peak_block_util"] = max(
            self.stats["peak_block_util"], self.blocks.utilization())
        self.stats["window_reclaimed_blocks"] = \
            self.blocks.window_freed_blocks

    def _run_chunks(self, mode: str, plan, pending, fresh):
        """Execute this step's planned prompt chunks. Attention-family
        descriptors fuse EVERY chunk into one batched ragged dispatch;
        recurrent descriptors dispatch per chunk (exact-length chunks,
        single-slot state routing). Returns the device array of sampled
        ids for the fused batch (None otherwise); completing rows are
        recorded in `pending`/`fresh` for the end-of-step sync."""
        if self._pad_chunks:
            return self._run_chunks_fused(mode, plan, pending, fresh)
        for idx, start, take in plan:
            # a COW-fork failure inside an earlier chunk may have
            # preempted a later plan entry — skip stale entries
            if idx in self.prefilling:
                self._run_chunk(mode, idx, start, take, pending, fresh)
        return None

    def _run_chunks_fused(self, mode: str, plan, pending, fresh):
        """ONE jitted ragged `paged_step` covers the whole chunk budget:
        rows bucketed to a power of two, chunk lengths to the max take's
        bucket; pad rows are disabled via kv_len=0 and pad columns are
        masked as before, so the fused batch is bit-identical to the
        per-chunk dispatches it replaces."""
        entries = []
        for idx, start, take in plan:
            if idx not in self.prefilling:
                continue                     # preempted by an earlier COW
            if not self._cow_or_preempt(idx, start, start + take):
                continue
            entries.append((idx, start, take))
        # a later COW fork may have preempted an earlier surviving entry
        entries = [e for e in entries if e[0] in self.prefilling]
        if not entries:
            return None
        rb = _bucket(len(entries), 1)
        cb = _bucket(max(take for _, _, take in entries))
        tokens = np.zeros((rb, cb), np.int32)
        rows = np.zeros(rb, np.int32)        # pad rows alias slot 0:
        qo = np.zeros(rb, np.int32)          # kv_len=0 masks their reads
        kvl = np.zeros(rb, np.int32)         # and trashes their writes
        lp = np.zeros(rb, np.int32)
        for r, (idx, start, take) in enumerate(entries):
            st = self.prefilling[idx]
            tokens[r, :take] = st.seq_tokens[start: start + take]
            rows[r] = idx
            qo[r] = start
            kvl[r] = start + take
            lp[r] = take - 1
        if self._host_tier:
            # the fused dispatch writes the pool: queued spill captures
            # go first, and any lo-pending block the write ranges touch
            # (the resume-boundary rewrite can land in a restored block
            # the row owns exclusively) completes its lo planes NOW —
            # a later whole-block lo scatter would clobber fresh bytes
            self._flush_spills()
            touched = [p for idx, start, take in entries
                       for p in self.blocks.lo_pending_in_range(
                           idx, start, start + take)]
            self._upload_lo(self.blocks.take_lo_pending_for(touched))
        ids, self.caches = self._fused_fn(mode, rb, cb)(
            self.params, self.caches, self._h2d(tokens),
            self.blocks.device_tables(), self._h2d(rows), self._h2d(qo),
            self._h2d(kvl), self._h2d(lp))
        self.stats["prefill_dispatches"] += 1
        for idx, start, take in entries:
            self._commit_chunk(idx, start, take)
        # sample pool pressure BEFORE _finish_chunk can retire+release
        # blocks — prefill-heavy steps used to under-report the peak
        self._sample_peak()
        for r, (idx, start, take) in enumerate(entries):
            self._finish_chunk(mode, idx, ids, r, pending, fresh)
        return ids

    def _run_chunk(self, mode: str, idx: int, start: int, take: int,
                   pending, fresh) -> None:
        """Recurrent-descriptor chunk: one dispatch per chunk (pads
        would be absorbed into the SSM state, so rows cannot share a
        bucketed batch)."""
        st = self.prefilling[idx]
        if not self._cow_or_preempt(idx, start, start + take):
            return
        bucket = take                        # exact-length, no padding
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :take] = st.seq_tokens[start: start + take]
        ids, self.caches = self._chunk_fn(mode, bucket)(
            self.params, self.caches, self._h2d(toks),
            self.blocks.device_tables(), jnp.int32(idx),
            self._h2d(np.asarray([start], np.int32)),
            self._h2d(np.asarray([start + take], np.int32)),
            self._h2d(np.asarray([take - 1], np.int32)), jnp.int32(idx))
        self.stats["prefill_dispatches"] += 1
        self._commit_chunk(idx, start, take)
        self._sample_peak()                  # pre-retire, as above
        self._finish_chunk(mode, idx, ids, 0, pending, fresh)

    def _commit_chunk(self, idx: int, start: int, take: int) -> None:
        st = self.prefilling[idx]
        st.done = start + take
        self.blocks.commit(idx, st.done, st.seq_tokens)
        self.stats["chunks"] += 1
        self.stats["chunk_tokens"] += take

    def _finish_chunk(self, mode: str, idx: int, ids, row: int,
                      pending, fresh) -> None:
        """Promote a prefill whose final chunk just ran to active. Its
        first generated token is still ON DEVICE (`ids[row]`): the
        output slot is patched at the end-of-step sync, and the same
        step's decode receives it through the jitted overlay."""
        st = self.prefilling[idx]
        if st.done < len(st.seq_tokens):
            return
        req = st.req
        req.output.append(_PENDING)
        pending.append((req, len(req.output) - 1, ids, row, idx))
        now = self.clock()
        if req.first_token_s is None:
            req.first_token_s = now
        req.token_times.append(now)
        req.modes.append(mode)
        self.lens[idx] = len(st.seq_tokens)
        self.active[idx] = req
        del self.prefilling[idx]
        self._maybe_retire(idx, now)
        if idx in self.active:
            fresh.append((idx, ids, row))

    def _preempt(self, victim: int) -> None:
        """vLLM-style recompute preemption: drop the victim's blocks and
        requeue its request at the FRONT of the queue; on re-admission it
        prefills prompt+generated-so-far and continues exactly."""
        self.stats["preemptions"] += 1
        if victim in self.active:
            req = self.active.pop(victim)
        else:
            req = self.prefilling.pop(victim).req
        self.blocks.release(victim)
        if self.slot_state is not None:
            self.slot_state.release(victim)
        self.lens[victim] = 0
        self.queue.appendleft(req)

    def _retire(self, idx: int, now: float) -> None:
        req = self.active.pop(idx)
        req.finished_s = now
        self.finished.append(req)
        self.blocks.release(idx)
        if self.slot_state is not None:
            self.slot_state.release(idx)
        self.lens[idx] = 0

    def _maybe_retire(self, idx: int, now: float) -> None:
        req = self.active[idx]
        # NOTE length >= capacity (not length+1): position `length` is the
        # next write target, so a row is live while length < capacity —
        # the old `+1` retired sequences one writable position early.
        # Stop-token retirement reads the LAST emitted token only: the
        # speculative multi-token path already cuts its emission at the
        # first stop token, so output[-1] is the one place EOS can live
        # (_PENDING placeholders are not yet tokens and never match).
        eos = bool(req.stop_tokens) and bool(req.output) \
            and req.output[-1] != _PENDING \
            and req.output[-1] in req.stop_tokens
        if eos or len(req.output) >= req.max_new \
                or self.lens[idx] >= self.capacity:
            self._retire(idx, now)

    def _draft(self) -> dict[int, list[int]]:
        """Propose n-gram drafts per active row and secure KV coverage
        for their writes at positions L+1..L+K. Drafting NEVER preempts:
        the draft is clamped to what the pool can cover without evicting
        anyone (`max_coverable`), and if the COW fork for the extension
        cannot complete the extension is given back (`truncate`) and the
        row runs as a plain one-token decode. Rows whose pending input
        token still lives on device (fresh prefills) cannot be matched
        against and draft nothing this step."""
        k = self._spec_k.decide(StepObservation(
            batch_tokens=max(len(self.active), 1),
            queue_depth=len(self.queue),
            measured_step_ms=self._last_step_ms,
            spec_drafted=self._last_spec[0],
            spec_accepted=self._last_spec[1]))
        drafts: dict[int, list[int]] = {}
        bm = self.blocks
        for idx, req in self.active.items():
            if req.output[-1] == _PENDING:
                continue
            L = int(self.lens[idx])
            # position L's write and this step's guaranteed token are
            # already budgeted — clamp drafts to what's left of the
            # output budget and the cache capacity beyond them
            budget = min(k, req.max_new - len(req.output) - 1,
                         self.capacity - L - 1)
            if budget <= 0:
                continue
            d = self._proposer.propose(req.tokens + req.output, budget)
            if d:
                d = d[:bm.max_coverable(idx, L + 1, len(d))]
            if not d:
                continue
            ok = bm.ensure(idx, L + 1 + len(d))
            assert ok, idx           # max_coverable guarantees coverage
            pairs = bm.cow_for_write(idx, L + 1, L + 1 + len(d))
            if pairs is None:
                bm.truncate(idx, L + 1)
                continue
            self._apply_cow(pairs)
            drafts[idx] = d
        return drafts

    def _decode_paged(self, mode: str, chunk_ids, fresh):
        """Dispatch the batched decode; returns (device ids, drafts) —
        ids None when nothing is active, drafts None for a plain
        one-token step. With speculation enabled and at least one row
        drafting, the decode runs through `_spec_fn` as a ragged C=K+1
        chunk instead (same single dispatch, packed [ids | n_accepted]
        result). Host bookkeeping for the decoded tokens happens in
        `_finalize_step` after the single end-of-step sync."""
        # grow each active row's block table to cover the incoming write
        # at position lens[idx] and COW-fork it if shared; preempt
        # youngest sequences on exhaustion
        for idx in sorted(self.active):
            while idx in self.active:
                if self.blocks.ensure(idx, int(self.lens[idx]) + 1):
                    if self._cow_or_preempt(idx, int(self.lens[idx]),
                                            int(self.lens[idx]) + 1):
                        break
                    continue                 # preempted (maybe ourselves)
                victim = self.blocks.youngest()
                if victim is None:
                    raise RuntimeError("KV pool exhausted with nothing "
                                       "preemptible")
                self._preempt(victim)
        self._sample_peak()                  # allocation peak, pre-retire
        if not self.active:
            return None, None
        drafts = self._draft() if self._spec is not None else {}
        kmax = max(map(len, drafts.values()), default=0)
        # no row drafted: dispatch the plain C=1 executable — identical
        # to speculation-off (under attn_backend="pallas" it keeps the
        # single-query decode kernel, which the C>1 chunk cannot use)
        cb = _bucket(kmax + 1, 1) if kmax else 1
        tokens = np.zeros((self.n_slots, cb), np.int32)
        q_off = np.zeros(self.n_slots, np.int32)
        kvl = np.zeros(self.n_slots, np.int32)   # 0 disables inactive rows
        dlen = np.zeros(self.n_slots, np.int32)
        for idx, req in self.active.items():
            if req.output[-1] != _PENDING:
                tokens[idx, 0] = req.output[-1]
            d = drafts.get(idx)
            if d:
                tokens[idx, 1:1 + len(d)] = d
                dlen[idx] = len(d)
            q_off[idx] = self.lens[idx]
            kvl[idx] = self.lens[idx] + 1 + dlen[idx]
        toks = self._h2d(tokens)
        fresh = [(s, a, r) for s, a, r in fresh if s in self.active]
        if fresh and chunk_ids is not None:
            # fused path: every completing prefill's first token lives in
            # ONE device array — overlay them all with a single jitted
            # scatter instead of syncing mid-step
            slots = np.asarray([s for s, _, _ in fresh], np.int32)
            rows = np.asarray([r for _, _, r in fresh], np.int32)
            toks = self._overlay(toks, self._h2d(slots), chunk_ids,
                                 self._h2d(rows))
            self.stats["aux_dispatches"] += 1
        elif fresh:
            # recurrent path: per-chunk ids arrays, one overlay each
            for s, a, r in fresh:
                toks = self._overlay(
                    toks, self._h2d(np.asarray([s], np.int32)), a,
                    self._h2d(np.asarray([r], np.int32)))
                self.stats["aux_dispatches"] += 1
        # decode writes the pool: capture queued spills (ensure() may
        # have evicted LRU prefix blocks above) before the write lands.
        # No lo guard here — decode/draft writes only ever land in
        # partially-filled or COW-exclusive tail blocks, never in a
        # restored (full, registered) block.
        self._flush_spills()
        if kmax:
            ids, self.caches = self._spec_fn(mode, cb)(
                self.params, self.caches, toks, self.blocks.device_tables(),
                self._h2d(q_off), self._h2d(kvl), self._h2d(dlen))
            self.stats["decode_dispatches"] += 1
            self.stats["spec_dispatches"] += 1
            return ids, drafts
        ids, self.caches = self._decode[mode](
            self.params, self.caches, toks, self.blocks.device_tables(),
            self._h2d(q_off), self._h2d(kvl))
        self.stats["decode_dispatches"] += 1
        return ids, None

    # nfp: sync-point
    def _finalize_step(self, mode: str, pending, decode_ids,
                       drafts=None) -> None:
        """The step's ONLY device->host sync: pull the sampled token ids
        (a few int32s, not logits), patch pending prefill outputs, then
        run decode bookkeeping — commit() must hash REAL token values,
        so it happens strictly after the patch.

        A patched pending token that is a stop token retires its row
        HERE, before decode bookkeeping: the row's same-step decode
        result is discarded (its position-L write went to an exclusive
        unregistered tail block, so releasing is clean) — previously a
        first-token EOS decoded on to max_new.

        Speculative steps (`drafts` non-None) emit per row the accepted
        draft prefix plus the model's next token — `[ids | n_acc]`
        packed by `_spec_fn` — cut at the first stop token and the
        max_new budget; `BlockManager.truncate` gives back the blocks
        covering rejected positions, and one commit() both registers any
        newly-filled blocks (a multi-token emission can fill several)
        and advances the length. The LAST emitted token is never in the
        cache — it is the next step's input, exactly as in plain
        decode."""
        nxt = None if decode_ids is None else np.asarray(decode_ids)
        now = self.clock()
        for req, pos, ids, row, idx in pending:
            req.output[pos] = int(np.asarray(ids)[row])
            if req.output[pos] in req.stop_tokens \
                    and self.active.get(idx) is req:
                self._retire(idx, now)
        if nxt is None:
            return
        if drafts is None:
            for idx, req in list(self.active.items()):
                self.lens[idx] += 1
                n = int(self.lens[idx])
                if n % self.block_size == 0:
                    # tail block just filled: register it in the prefix
                    # index (generated content is reusable too — replays
                    # after preemption and shared multi-turn history)
                    self.blocks.commit(idx, n,
                                       (req.tokens + req.output)[:n])
                else:
                    self.blocks.set_length(idx, n)
                req.output.append(int(nxt[idx]))
                req.token_times.append(now)
                req.modes.append(mode)
                self.stats["decode_rows"] += 1
                self.stats["decode_tokens"] += 1
                self._maybe_retire(idx, now)
            if self._spec is not None:
                self._last_spec = (0, 0)
            return
        drafted_total = accepted_total = 0
        for idx, req in list(self.active.items()):
            d = drafts.get(idx, ())
            n_acc = int(nxt[idx, -1]) if d else 0
            out = [int(t) for t in nxt[idx, :n_acc + 1]]
            drafted_total += len(d)
            accepted_total += n_acc
            # EOS stops an accepted run MID-RUN: everything after the
            # first stop token is discarded (never emitted), and the
            # output budget bounds the emission the same way
            for j, t in enumerate(out):
                if t in req.stop_tokens:
                    out = out[:j + 1]
                    break
            out = out[:req.max_new - len(req.output)]
            new_n = int(self.lens[idx]) + len(out)
            # rollback: drop the blocks covering rejected positions
            # (their writes landed in COW-exclusive unregistered blocks;
            # what survives inside the kept tail block beyond new_n is
            # masked by kv_len and overwritten before it can be read)
            self.blocks.truncate(idx, new_n)
            self.blocks.commit(idx, new_n,
                               (req.tokens + req.output + out)[:new_n])
            self.lens[idx] = new_n
            req.output.extend(out)
            req.token_times.extend([now] * len(out))
            req.modes.extend([mode] * len(out))
            self.stats["decode_rows"] += 1
            self.stats["decode_tokens"] += len(out)
            self._maybe_retire(idx, now)
        self.stats["spec_drafted"] += drafted_total
        self.stats["spec_accepted"] += accepted_total
        self._last_spec = (drafted_total, accepted_total)

