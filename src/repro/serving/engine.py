"""Continuous-batching serving engine with per-iteration dual precision.

ORCA-style iteration-level scheduling: each engine step admits queued
requests into free slots (prefill) and advances all active slots by one
token (batched decode). The DualPrecisionController picks FP16 or FP8 per
iteration; because NestedFP serves both precisions from the same
weight buffers, the switch costs nothing — the engine simply dispatches
to the other pre-compiled executable (paper §5.3 "per-iteration precision
switching").

Greedy sampling; prompt lengths are bucketed to limit prefill recompiles.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.policy import DualPrecisionController, StepObservation
from repro.models import model as M
from repro.models.layers import Runtime
from repro.serving.kvcache import SlotManager


@dataclasses.dataclass
class Request:
    request_id: str
    tokens: list[int]
    max_new: int
    arrival_s: float = 0.0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    finished_s: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    modes: list[str] = dataclasses.field(default_factory=list)


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, cfg: ArchConfig, serving_params, *, n_slots: int,
                 capacity: int, controller: DualPrecisionController | None = None,
                 forced_mode: str | None = None, backend: str = "ref",
                 kv_planar: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.params = serving_params
        self.slots = SlotManager(n_slots, capacity)
        self.controller = controller
        self.forced_mode = forced_mode
        self.kv_planar = kv_planar and cfg.family in ("dense", "moe", "vlm") \
            and cfg.mla is None
        self.clock = clock
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.caches = M.init_cache(cfg, n_slots, capacity,
                                   planar=self.kv_planar)
        self.lens = np.zeros(n_slots, np.int32)
        self._rts = {m: Runtime(mode=m, backend=backend, dtype=jnp.float32)
                     for m in ("fp16", "fp8")}
        self._decode = {
            m: jax.jit(lambda p, c, t, l, _m=m: M.decode_step(
                self._rts[_m], p, cfg, t, c, l))
            for m in ("fp16", "fp8")}
        self._prefill_cache: dict[tuple[str, int], Any] = {}
        self.iteration = 0

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        while (self.queue or self.active) and self.iteration < max_iters:
            self.step()
        return self.finished

    # -- internals ------------------------------------------------------------
    def _mode(self, batch_tokens: int) -> str:
        if self.forced_mode:
            return self.forced_mode
        if self.controller is None:
            return "fp16"
        obs = StepObservation(batch_tokens=batch_tokens,
                              queue_depth=len(self.queue),
                              measured_step_ms=None)
        return self.controller.decide(obs)

    def _prefill_fn(self, mode: str, bucket: int, plen: int):
        """Prompts are RIGHT-padded to `bucket` for attention archs (causal
        masking makes the pad suffix invisible to real tokens; the pad
        region of the cache is masked out by per-slot lengths). SSM/hybrid
        state would absorb pad tokens, so those archs prefill at exact
        length (bucket == plen)."""
        key = (mode, bucket, plen)
        if key not in self._prefill_cache:
            rt = self._rts[mode]
            cfg = self.cfg

            def fn(p, tokens):
                logits, caches, _ = M.prefill(rt, p, cfg,
                                              {"tokens": tokens},
                                              capacity=self.slots.capacity,
                                              logit_position=plen - 1)
                if self.kv_planar:
                    caches = M.planarize_cache(caches)
                return logits, caches
            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _admit(self, mode: str) -> None:
        if self.cfg.family == "encdec":
            raise NotImplementedError(
                "engine serves decoder-only archs; enc-dec serving is "
                "covered by the dry-run + benchmarks")
        pad_ok = self.cfg.family in ("dense", "moe", "vlm")
        while self.queue and self.slots.n_free() > 0:
            req = self.queue[0]
            idx = self.slots.try_allocate(req.request_id, len(req.tokens),
                                          req.max_new)
            if idx is None:
                return
            self.queue.pop(0)
            plen = len(req.tokens)
            bucket = _bucket(plen) if pad_ok else plen
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = req.tokens               # right-pad
            logits, pc = self._prefill_fn(mode, bucket, plen)(
                self.params, jnp.asarray(toks))
            # install the prefilled caches into the slot
            self.caches = jax.tree.map(
                lambda full, one: full.at[:, idx].set(
                    one[:, 0].astype(full.dtype))
                if full.ndim >= 2 else full, self.caches, pc)
            self.lens[idx] = plen
            tok = int(np.asarray(jnp.argmax(logits, -1))[0])
            req.output.append(tok)
            now = self.clock()
            req.first_token_s = now
            req.token_times.append(now)
            req.modes.append(mode)
            self.active[idx] = req
            self.slots.slots[idx].generated = 1

    def step(self) -> None:
        self.iteration += 1
        batch_tokens = len(self.active) + sum(
            len(r.tokens) for r in self.queue[: self.slots.n_free()])
        mode = self._mode(max(batch_tokens, 1))
        self._admit(mode)
        if not self.active:
            return
        tokens = np.zeros((self.slots.n_slots, 1), np.int32)
        for idx, req in self.active.items():
            tokens[idx, 0] = req.output[-1]
        logits, self.caches = self._decode[mode](
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.lens))
        nxt = np.asarray(jnp.argmax(logits, -1))
        now = self.clock()
        done = []
        for idx, req in list(self.active.items()):
            self.lens[idx] += 1
            req.output.append(int(nxt[idx]))
            req.token_times.append(now)
            req.modes.append(mode)
            slot = self.slots.slots[idx]
            slot.generated += 1
            slot.length += 1
            if slot.generated >= req.max_new \
                    or slot.length + 1 >= self.slots.capacity:
                req.finished_s = now
                done.append(idx)
        for idx in done:
            self.finished.append(self.active.pop(idx))
            self.slots.release(idx)
            self.lens[idx] = 0
