"""Fault-tolerant multi-replica serving router.

One `Router` owns R `Engine` replicas (each optionally committed to its
own `make_serving_mesh` slice — `launch.mesh.make_replica_meshes` cuts
disjoint ones) and fronts admission for all of them:

* **Prefix-affinity placement.** Requests are keyed by the stable
  blake2b chain hash (`kvcache._chain_hash`) of their leading prompt
  blocks — the same content digest the prefix index and the persistent
  store use — and placed by rendezvous hashing over the ALIVE replicas:
  shared-prefix tenants land on the same warm replica, and a kill only
  re-homes the dead replica's keys instead of reshuffling the fleet.
  A load gap beyond `balance_slack_tokens` overrides affinity with the
  least-loaded replica.

* **Health state machine.** healthy → degraded (a step raised; work
  drained + failed over, replica stays in service) → dead (consecutive
  errors, or a planned kill) → recovering (revived; probation) →
  healthy. Dead replicas receive no work; recovering ones do.

* **Drain + deterministic failover.** On failure the replica's
  in-flight requests are exported (`Engine.drain_requests`), then
  re-submitted to survivors. Re-prefilling prompt + already-emitted
  tokens continues greedy generation EXACTLY (the engine's recompute
  replay invariant — generation is batch-invariant, so outputs are
  bit-identical to a no-fault run). KV comes back through the
  survivor's prefix cache / host tier where chains match (counted as
  restored tokens) and is recomputed otherwise (also counted). If the
  drain itself fails, requests are recovered from the router's own
  registry and the engine is rebuilt from its factory.

* **Graceful degradation.** A `core.policy.DegradePolicy` drives the
  NestedFP knob when live capacity drops: survivors are pinned to FP8
  (same weights, iteration-granular switch), new admissions beyond a
  per-replica outstanding-token budget are shed (explicitly, never
  silently lost), and tiered-KV restore grants tighten. Recovery
  re-probes FP16 only after a hysteresis dwell.

For deterministic latency accounting the router accepts a shared
`VirtualClock` plus a `StepCostModel`: each router step advances the
clock by the slowest replica's modeled step time (including injected
stalls), so TTFT/TPOT percentiles — and the degrade-vs-no-degrade SLO
comparison in `bench_slo_trace` — are exact functions of the schedule,
not of host noise.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from ..core.policy import DegradeDecision, DegradePolicy
from .engine import Request, _PENDING
from .faults import FaultInjector, FaultPlan, ROUTER_KINDS
from .kvcache import _ROOT_HASH, _chain_hash

HEALTHY, DEGRADED, DEAD, RECOVERING = \
    "healthy", "degraded", "dead", "recovering"


class VirtualClock:
    """A monotonic clock the caller advances — share one instance as
    every replica's `clock=` so arrival gating, TTFT/TPOT stamps, and
    the router's step costs all read the same deterministic time."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt_s: float) -> None:
        assert dt_s >= 0.0
        self.now += dt_s


@dataclasses.dataclass
class StepCostModel:
    """Modeled per-replica step latency: fixed overhead + per-token
    cost by precision mode (FP8 cheaper — the whole point of degrading
    into it). Decode tokens pay the full memory-bound per-step rate;
    prefill-chunk tokens ride a cheaper compute-bound rate (they batch
    into one ragged dispatch and amortize the weight reads)."""
    fixed_ms: float = 2.0
    ms_per_token: dict[str, float] = dataclasses.field(
        default_factory=lambda: {"fp16": 4.0, "fp8": 2.0})
    prefill_ms_per_token: dict[str, float] = dataclasses.field(
        default_factory=lambda: {"fp16": 1.0, "fp8": 0.5})

    def step_ms(self, mode: str | None, decode_tokens: int,
                prefill_tokens: int = 0) -> float:
        m = mode or "fp16"
        return (self.fixed_ms + self.ms_per_token[m] * decode_tokens
                + self.prefill_ms_per_token[m] * prefill_tokens)


@dataclasses.dataclass
class _Replica:
    rid: int
    engine: object
    factory: Callable[[], object] | None = None
    state: str = HEALTHY
    errors: int = 0          # consecutive failed steps
    clean: int = 0           # consecutive clean steps since last error
    usable: bool = True      # False: broken beyond rebuild, never revive
    fin_cursor: int = 0      # engine.finished entries already collected
    fp8_dwell: int = 0       # steps this replica spent policy-pinned to FP8
    saved: tuple | None = None           # (forced_mode, restore_policy)

    @property
    def serving(self) -> bool:
        return self.state != DEAD


class Router:
    """R-replica front: placement, health, failover, degradation."""

    def __init__(self, engines: list, *,
                 policy: DegradePolicy | None = None,
                 plan: FaultPlan | None = None,
                 factories: list[Callable[[], object] | None] | None = None,
                 clock: VirtualClock | None = None,
                 cost_model: StepCostModel | None = None,
                 affinity_blocks: int = 2,
                 balance_slack_tokens: int = 512,
                 dead_after_errors: int = 2,
                 heal_steps: int = 4,
                 recover_probe_steps: int = 4,
                 block_size: int | None = None):
        if not engines:
            raise ValueError("router needs at least one replica")
        factories = factories or [None] * len(engines)
        self.replicas = [_Replica(i, e, f)
                         for i, (e, f) in enumerate(zip(engines, factories))]
        self.policy = policy
        self.clock = clock
        self.cost_model = cost_model
        self.affinity_blocks = affinity_blocks
        self.balance_slack_tokens = balance_slack_tokens
        self.dead_after_errors = dead_after_errors
        self.heal_steps = heal_steps
        self.recover_probe_steps = recover_probe_steps
        self.block_size = block_size if block_size is not None \
            else getattr(engines[0], "block_size", 16)
        self.step_count = 0
        self.finished: list[Request] = []
        self.shed_requests: list[Request] = []
        self._live: dict[int, dict[str, Request]] = \
            {r.rid: {} for r in self.replicas}
        self._orphans: list[Request] = []    # in-flight with zero survivors
        self._decision: DegradeDecision | None = None
        self._submitted = 0
        self._shed_by: dict[int, int] = {r.rid: 0 for r in self.replicas}
        self._c = {"kills": 0, "revives": 0, "step_errors": 0,
                   "rebuilds": 0, "failovers": 0, "failover_requests": 0,
                   "failover_restored_tokens": 0,
                   "failover_recomputed_tokens": 0,
                   "degrade_fp8_steps": 0, "stall_ms": 0.0}
        self.injector = FaultInjector(plan) if plan is not None else None
        self._router_events: dict[int, list] = {}
        if plan is not None:
            for ev in plan.events:
                if ev.kind in ROUTER_KINDS:
                    self._router_events.setdefault(ev.step, []).append(ev)
            for rep in self.replicas:
                rep.engine.fault_hook = self.injector.hook(rep.rid)

    # -- placement ------------------------------------------------------------
    def _affinity_key(self, tokens) -> int:
        """Chain hash of the request's leading `affinity_blocks` prompt
        blocks — the prefix identity warm KV would be shared under. A
        short prompt hashes whatever it has (stable either way)."""
        bs = self.block_size
        h = _ROOT_HASH
        for i in range(max(1, min(self.affinity_blocks,
                                  -(-len(tokens) // bs)))):
            h = _chain_hash(h, tuple(tokens[i * bs: (i + 1) * bs]))
        return h

    def _outstanding(self, rep: _Replica) -> int:
        """Tokens of work still owed by replica `rep`: remaining
        generation + unprefilled prompt across its registered
        requests (router-side bookkeeping — no engine sync)."""
        return sum(len(r.tokens) + r.max_new - len(r.output)
                   for r in self._live[rep.rid].values())

    def _place(self, tokens, among: list[_Replica] | None = None
               ) -> _Replica | None:
        """Rendezvous-hash the affinity key over candidate replicas:
        each (key, replica) pair gets a stable score, the max wins — so
        removing a replica re-homes ONLY its keys. A load imbalance
        beyond `balance_slack_tokens` falls back to least-loaded."""
        cands = among if among is not None \
            else [r for r in self.replicas if r.serving]
        if not cands:
            return None
        key = self._affinity_key(tokens)
        primary = max(cands, key=lambda r: _chain_hash(key, (r.rid,)))
        least = min(cands, key=lambda r: (self._outstanding(r), r.rid))
        if self._outstanding(primary) - self._outstanding(least) \
                > self.balance_slack_tokens:
            return least
        return primary

    # -- admission ------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Place and enqueue one request. Returns False iff the request
        was SHED: degrade mode is active and every serving replica is
        beyond the policy's outstanding-token budget (the shed is
        recorded — shed work is never silently lost work)."""
        cands = [r for r in self.replicas if r.serving]
        if not cands:
            raise RuntimeError("no serving replicas")
        d = self._decision
        if d is not None and d.active and d.shed_budget_tokens is not None:
            est = len(req.tokens) + req.max_new
            within = [r for r in cands
                      if self._outstanding(r) + est <= d.shed_budget_tokens]
            if not within:
                primary = self._place(req.tokens, among=cands)
                self._shed_by[primary.rid] += 1
                self.shed_requests.append(req)
                self._submitted += 1
                return False
            cands = within
        target = self._place(req.tokens, among=cands)
        target.engine.submit(req)            # may raise: invalid requests
        self._submitted += 1                 # are the caller's bug
        self._live[target.rid][req.request_id] = req
        return True

    # -- failure containment --------------------------------------------------
    def _sanitize(self, req: Request) -> Request:
        """Strip an interrupted step's trailing `_PENDING` placeholder
        (mirror of `Engine.drain_requests`' sanitization, for requests
        recovered from the router's registry instead)."""
        while req.output and req.output[-1] == _PENDING:
            req.output.pop()
            if req.token_times:
                req.token_times.pop()
            if req.modes:
                req.modes.pop()
        if not req.output:
            req.first_token_s = None
        return req

    def _restore_overrides(self, rep: _Replica) -> None:
        if rep.saved is not None:
            rep.engine.forced_mode, rep.engine.restore_policy = rep.saved
            rep.saved = None

    def _drain(self, rep: _Replica) -> list[Request]:
        """Export a failed replica's in-flight requests. If the drain
        itself fails (the engine is inconsistent beyond its containment
        point), recover the requests from the router's registry and
        rebuild the engine from its factory — a replica without a
        factory is marked unusable and stays dead."""
        try:
            return rep.engine.drain_requests()
        except Exception:
            reqs = [self._sanitize(r)
                    for r in self._live[rep.rid].values()]
            if rep.factory is not None:
                rep.engine = rep.factory()
                rep.fin_cursor = 0
                rep.saved = None
                if self.injector is not None:
                    rep.engine.fault_hook = self.injector.hook(rep.rid)
                self._c["rebuilds"] += 1
            else:
                rep.state = DEAD
                rep.usable = False
            return reqs

    def _failover(self, rep: _Replica, reqs: list[Request]) -> None:
        """Re-home drained requests on the surviving replicas,
        counting, per request, the prefix tokens a survivor can serve
        from its own warm KV (device cache, host tier, or persisted
        store — chains are stable content hashes, so they match across
        replicas) vs. the tokens it must recompute."""
        if reqs:
            self._c["failovers"] += 1
        survivors = [r for r in self.replicas
                     if r.serving and r is not rep]
        if not survivors and rep.serving:
            survivors = [rep]                # sole replica: requeue on self
        for req in reqs:
            self._live[rep.rid].pop(req.request_id, None)
            self._resubmit(req, survivors)

    def _resubmit(self, req: Request, survivors: list[_Replica]) -> None:
        if not survivors:
            self._orphans.append(req)        # parked until a revive
            return
        target = self._place(req.tokens, among=survivors)
        seq = req.tokens + req.output
        bm = getattr(target.engine, "blocks", None)
        matched = bm.lookup_prefix(seq, allow_host=True) \
            if bm is not None else 0
        self._c["failover_requests"] += 1
        self._c["failover_restored_tokens"] += matched
        self._c["failover_recomputed_tokens"] += max(len(seq) - matched, 0)
        target.engine.submit(req)            # already-admitted work is
        self._live[target.rid][req.request_id] = req   # never shed

    def _on_step_error(self, rep: _Replica) -> None:
        rep.errors += 1
        rep.clean = 0
        self._c["step_errors"] += 1
        rep.state = DEAD if rep.errors >= self.dead_after_errors \
            else DEGRADED
        if rep.state == DEAD:
            self._restore_overrides(rep)
        self._failover(rep, self._drain(rep))

    def _kill(self, rep: _Replica) -> None:
        if not rep.serving:
            return
        rep.state = DEAD
        rep.errors = 0
        self._c["kills"] += 1
        self._restore_overrides(rep)
        self._failover(rep, self._drain(rep))

    def _revive(self, rep: _Replica) -> None:
        if rep.state != DEAD or not rep.usable:
            return
        rep.state = RECOVERING
        rep.clean = 0
        self._c["revives"] += 1

    def _promote(self, rep: _Replica) -> None:
        if rep.state == DEGRADED and rep.clean >= self.heal_steps:
            rep.state = HEALTHY
        elif rep.state == RECOVERING \
                and rep.clean >= self.recover_probe_steps:
            rep.state = HEALTHY

    # -- degradation ----------------------------------------------------------
    def _apply_degrade(self) -> None:
        if self.policy is None:
            return
        live = sum(1 for r in self.replicas if r.serving)
        d = self.policy.decide(live, len(self.replicas))
        self._decision = d
        for rep in self.replicas:
            if not rep.serving:
                continue
            if d.active:
                if rep.saved is None:
                    rep.saved = (rep.engine.forced_mode,
                                 rep.engine.restore_policy)
                    rep.engine.restore_policy = \
                        rep.saved[1].scaled(d.restore_scale)
                if d.force_fp8:
                    rep.engine.forced_mode = "fp8"
                    rep.fp8_dwell += 1
                    self._c["degrade_fp8_steps"] += 1
            else:
                self._restore_overrides(rep)

    # -- stepping -------------------------------------------------------------
    def _busy(self, rep: _Replica) -> bool:
        e = rep.engine
        return bool(e.queue or e.active or e.prefilling)

    def in_flight(self) -> int:
        return sum(len(v) for v in self._live.values()) + len(self._orphans)

    def step(self) -> None:
        """One fleet iteration: fire this step's planned kill/revive
        events, re-home any orphans, step every serving replica inside
        its failure containment, collect completions, drive the degrade
        policy, and advance the shared clock by the slowest replica's
        modeled step cost."""
        s = self.step_count
        if self.injector is not None:
            self.injector.arm(s)
        # revives before kills: a seeded plan may schedule both in one
        # step, and its no-extinction guarantee assumes this ordering
        for ev in sorted(self._router_events.pop(s, ()),
                         key=lambda e: e.kind != "revive"):
            if not 0 <= ev.replica < len(self.replicas):
                continue                     # plan sized for a larger fleet
            rep = self.replicas[ev.replica]
            self._kill(rep) if ev.kind == "kill" else self._revive(rep)
        if self._orphans and any(r.serving for r in self.replicas):
            orphans, self._orphans = self._orphans, []
            for req in orphans:
                self._resubmit(req,
                               [r for r in self.replicas if r.serving])
        step_ms = 0.0
        for rep in self.replicas:
            if not rep.serving:
                continue
            if not self._busy(rep):
                rep.clean += 1               # idle steps are clean steps:
                self._promote(rep)           # probation can pass on a
                continue                     # quiet fleet
            mark = self._token_counts(rep)
            try:
                rep.engine.step()
            except Exception:
                self._on_step_error(rep)
                continue
            rep.errors = 0
            rep.clean += 1
            self._promote(rep)
            if self.cost_model is not None:
                now = self._token_counts(rep)
                stall = float(getattr(rep.engine, "last_stall_ms", 0.0))
                self._c["stall_ms"] += stall
                step_ms = max(step_ms, stall + self.cost_model.step_ms(
                    getattr(rep.engine, "last_mode", None),
                    now[0] - mark[0], now[1] - mark[1]))
        self._collect_finished()
        self._apply_degrade()
        if self.clock is not None and self.cost_model is not None:
            self.clock.advance(max(step_ms, self.cost_model.fixed_ms) / 1e3)
        self.step_count += 1

    @staticmethod
    def _token_counts(rep: _Replica) -> tuple[int, int]:
        """(decode, prefill-chunk) token counters — deltas across one
        step feed the StepCostModel."""
        stats = getattr(rep.engine, "stats", None)
        if not stats:
            return 0, 0
        return stats.get("decode_tokens", 0), stats.get("chunk_tokens", 0)

    def _collect_finished(self) -> None:
        for rep in self.replicas:
            fin = rep.engine.finished
            while rep.fin_cursor < len(fin):
                req = fin[rep.fin_cursor]
                rep.fin_cursor += 1
                self._live[rep.rid].pop(req.request_id, None)
                self.finished.append(req)

    def run(self, max_steps: int = 10_000,
            allow_partial: bool = False) -> list[Request]:
        """Step until every submitted request is retired (or shed).
        Stuck states — work in flight but zero serving replicas and no
        planned revive, or the step cap — raise unless
        `allow_partial=True`."""
        steps = 0
        while self.in_flight() and steps < max_steps:
            if not any(r.serving for r in self.replicas) \
                    and not self._router_events:
                break                        # nothing can ever progress
            self.step()
            steps += 1
        if self.in_flight() and not allow_partial:
            raise RuntimeError(
                f"run(max_steps={max_steps}) ended with "
                f"{self.in_flight()} requests in flight "
                f"(serving replicas: "
                f"{sum(1 for r in self.replicas if r.serving)})")
        return self.finished

    # -- reporting ------------------------------------------------------------
    def stats(self) -> dict:
        """Conservation + health + degradation accounting. `lost` MUST
        be zero: every submitted request is exactly-once completed,
        shed, or still in flight."""
        inflight = self.in_flight()
        corrupt_detected = 0
        corrupt_fallbacks = 0
        for rep in self.replicas:
            host = getattr(getattr(rep.engine, "blocks", None),
                           "host", None)
            if host is not None:
                corrupt_detected += host.stats.get("corrupt_blocks", 0)
            estats = getattr(rep.engine, "stats", None)
            if estats:
                corrupt_fallbacks += estats.get("corrupt_fallbacks", 0)
        return {"steps": self.step_count,
                "replicas": {r.rid: r.state for r in self.replicas},
                "submitted": self._submitted,
                "completed": len(self.finished),
                "shed": len(self.shed_requests),
                "in_flight": inflight,
                "lost": self._submitted - len(self.finished)
                - len(self.shed_requests) - inflight,
                "degrade_active": bool(self._decision is not None
                                       and self._decision.active),
                "fp8_dwell": {r.rid: r.fp8_dwell for r in self.replicas},
                "shed_by_replica": dict(self._shed_by),
                "corrupt_detected": corrupt_detected,
                "corrupt_fallbacks": corrupt_fallbacks,
                **self._c}

    # -- construction helper --------------------------------------------------
    @classmethod
    def build(cls, cfg, serving_params, n_replicas: int, *,
              meshes: list | None = None,
              engine_kwargs: dict | None = None,
              **router_kwargs) -> "Router":
        """Build R identical engines (optionally one per mesh slice)
        with rebuild factories retained for drain-failure recovery."""
        from .engine import Engine
        base = dict(engine_kwargs or {})
        factories = []
        for i in range(n_replicas):
            kw = dict(base)
            if meshes is not None:
                kw["mesh"] = meshes[i]

            def factory(kw=kw):
                return Engine(cfg, serving_params, **kw)
            factories.append(factory)
        return cls([f() for f in factories], factories=factories,
                   **router_kwargs)
