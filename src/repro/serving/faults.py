"""Deterministic fault injection for multi-replica serving.

A `FaultPlan` is an explicit, serializable list of `FaultEvent`s keyed
by (router step, replica) — either hand-authored or drawn from a seeded
RNG (`FaultPlan.seeded`), and always REPLAYABLE: the same plan against
the same trace produces the same failure interleaving, which is what
lets the router tests assert bit-exact failover against a no-fault run.

Event kinds and where they land:

* ``raise``   — the replica's next `Engine.step` raises `InjectedFault`
  at the engine's containment point (top of `_step_inner`, before any
  state mutates), modeling a crashed iteration.
* ``stall``   — adds `arg` virtual milliseconds to the step
  (`Engine.inject_stall_ms`): the engine folds it into its measured
  step time (so the dual-precision controller reacts) and the router's
  step-cost clock advances by it.
* ``corrupt`` — flips one byte of a deterministically-chosen host-tier
  entry, modeling spill-payload bit rot. The blake2b checksums recorded
  at spill time (`HostPool.put`) catch it at match/restore time and the
  engine falls back to recompute — counted, never a crash, never a
  wrong token.
* ``kill`` / ``revive`` — consumed by the Router itself: the replica is
  removed from (returned to) service, with in-flight work drained and
  failed over.

The engine-side kinds execute through `FaultInjector.hook(replica)`,
installed as `Engine.fault_hook` and armed with the current router step
each iteration.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


class InjectedFault(RuntimeError):
    """A deliberately-injected step failure (never a real defect)."""


ENGINE_KINDS = ("raise", "stall", "corrupt")
ROUTER_KINDS = ("kill", "revive")
KINDS = ENGINE_KINDS + ROUTER_KINDS


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    step: int                # router step at which the event fires
    replica: int
    kind: str                # one of KINDS
    arg: float = 0.0         # stall milliseconds (kind == "stall")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")


@dataclasses.dataclass
class FaultPlan:
    """An ordered, replayable fault schedule."""
    events: list[FaultEvent] = dataclasses.field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.events = sorted(self.events)

    # -- serialization (replay a plan across processes) -----------------------
    def to_dict(self) -> dict:
        return {"seed": self.seed,
                "events": [[e.step, e.replica, e.kind, e.arg]
                           for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(events=[FaultEvent(int(s), int(r), k, float(a))
                           for s, r, k, a in d["events"]],
                   seed=int(d["seed"]))

    # -- seeded generation ----------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, *, replicas: int, steps: int,
               p_raise: float = 0.0, p_stall: float = 0.0,
               p_corrupt: float = 0.0, p_kill: float = 0.0,
               stall_ms: float = 50.0, revive_after: int | None = 10
               ) -> "FaultPlan":
        """Draw a random-but-deterministic plan. Replica/step order is
        fixed, so the same seed always yields the same schedule. A kill
        is only drawn while at least two replicas are alive (the
        harness degrades the fleet, it never extinguishes it), and each
        kill schedules a revive `revive_after` steps later unless
        revives are disabled (None)."""
        rng = np.random.RandomState(seed)
        events: list[FaultEvent] = []
        dead: dict[int, int | None] = {}     # rid -> revive step (or None)
        for s in range(steps):
            for rid, at in list(dead.items()):
                if at is not None and at <= s:
                    events.append(FaultEvent(s, rid, "revive"))
                    del dead[rid]
            for rid in range(replicas):
                if rid in dead:
                    continue
                if p_kill and rng.rand() < p_kill \
                        and replicas - len(dead) > 1:
                    events.append(FaultEvent(s, rid, "kill"))
                    dead[rid] = None if revive_after is None \
                        else s + revive_after
                    continue
                if p_raise and rng.rand() < p_raise:
                    events.append(FaultEvent(s, rid, "raise"))
                if p_stall and rng.rand() < p_stall:
                    events.append(FaultEvent(s, rid, "stall", stall_ms))
                if p_corrupt and rng.rand() < p_corrupt:
                    events.append(FaultEvent(s, rid, "corrupt"))
        return cls(events=events, seed=seed)


class FaultInjector:
    """Executes a plan's ENGINE-side events through `Engine.fault_hook`.

    The router arms the injector with the current router step, then
    steps its replicas; each replica's hook fires the events scheduled
    for (step, replica) exactly once. Within one step, stall/corrupt
    execute before a raise (the raise aborts the engine step, it must
    not swallow its co-scheduled events)."""

    def __init__(self, plan: FaultPlan):
        self.seed = plan.seed
        self.step = 0
        self.fired: list[FaultEvent] = []
        self._queue: dict[tuple[int, int], list[FaultEvent]] = \
            collections.defaultdict(list)
        order = {"stall": 0, "corrupt": 1, "raise": 2}
        for ev in plan.events:
            if ev.kind in ENGINE_KINDS:
                self._queue[(ev.step, ev.replica)].append(ev)
        for q in self._queue.values():
            q.sort(key=lambda e: order[e.kind])

    def arm(self, step: int) -> None:
        self.step = step

    def hook(self, replica: int):
        """The `Engine.fault_hook` callable for one replica."""
        def _hook(engine) -> None:
            for ev in self._queue.pop((self.step, replica), []):
                self.fired.append(ev)
                if ev.kind == "stall":
                    engine.inject_stall_ms += ev.arg
                elif ev.kind == "corrupt":
                    self._corrupt(engine, ev)
                else:
                    raise InjectedFault(
                        f"injected step failure @ step {ev.step} "
                        f"replica {ev.replica}")
        return _hook

    def _corrupt(self, engine, ev: FaultEvent) -> None:
        """Flip one byte of one host-tier entry, chosen by a
        per-event-deterministic RNG (independent of how many entries
        other replicas hold). No-op when the tier is empty."""
        host = getattr(getattr(engine, "blocks", None), "host", None)
        if host is None or not len(host.entries):
            return
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + ev.step * 1_009 + ev.replica * 101)
            % (2 ** 31))
        key = sorted(host.entries)[rng.randint(len(host.entries))]
        planes = host.entries[key]
        name = sorted(planes)[rng.randint(len(planes))]
        arr = planes[name]
        if not arr.flags.writeable:
            # spill capture hands HostPool read-only device_get arrays;
            # rot must land in the POOL's entry, so rebind a mutable copy
            arr = arr.copy()
            planes[name] = arr
        buf = arr.view(np.uint8).reshape(-1)
        if buf.size:
            buf[rng.randint(buf.size)] ^= 0xFF
