"""Bursty request-arrival traces (paper §3.1, Fig. 1a).

The Azure LLM inference trace is not available offline; `azure_like()`
reproduces its published statistics instead: per-second rates in [0, 100]
with ~5.8x swings inside the most variable hour and ~3.2x inside the most
variable minute, via a slowly-varying base load + Poisson thinning +
random spikes. All generators are seeded/deterministic.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TraceRequest:
    arrival_s: float
    prompt_len: int
    max_new: int


def azure_like(duration_s: float = 60.0, mean_rate: float = 5.0,
               seed: int = 0, prompt_len: int = 256, max_new: int = 512,
               spike_factor: float = 3.2, spike_prob: float = 0.05
               ) -> list[TraceRequest]:
    """Bursty arrivals: sinusoidal base + random multiplicative spikes,
    Poisson sampled per second (downscaled trace used in paper Fig. 1b:
    1–11 req/s, avg ~5)."""
    rng = np.random.RandomState(seed)
    reqs: list[TraceRequest] = []
    t = 0.0
    while t < duration_s:
        phase = 0.5 + 0.5 * np.sin(2 * np.pi * t / 37.0)          # slow wave
        rate = mean_rate * (0.4 + 1.2 * phase)
        if rng.rand() < spike_prob:
            rate *= spike_factor
        n = rng.poisson(rate)
        for _ in range(n):
            jitter = rng.rand()
            plen = max(8, int(rng.lognormal(np.log(prompt_len), 0.4)))
            mnew = max(4, int(rng.lognormal(np.log(max_new), 0.3)))
            # clamp: jitter in the final second must not push an arrival
            # past the trace end (callers size runs by duration_s)
            reqs.append(TraceRequest(min(t + jitter, duration_s), plen, mnew))
        t += 1.0
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def steady(duration_s: float, rate: float, seed: int = 0,
           prompt_len: int = 256, max_new: int = 512) -> list[TraceRequest]:
    rng = np.random.RandomState(seed)
    n = rng.poisson(rate * duration_s)
    times = np.sort(rng.uniform(0, duration_s, n))
    return [TraceRequest(float(t), prompt_len, max_new) for t in times]


def rate_stats(reqs: list[TraceRequest], duration_s: float) -> dict:
    """Per-second arrival-rate stats over exactly ceil(duration_s)
    buckets. (The old `int(duration_s) + 1` sizing padded a phantom
    final bucket: `mean_rate` was biased low by duration/(duration+1)
    and the empty pad polluted `min_rate`.) An arrival clamped to
    exactly `duration_s` counts in the last real second."""
    nbins = max(int(np.ceil(duration_s)), 1)
    counts = np.zeros(nbins)
    for r in reqs:
        counts[min(int(r.arrival_s), nbins - 1)] += 1
    nz = counts[counts > 0]
    # an EMPTY trace (every request shed, or a fault window with no
    # arrivals) has no nonzero bucket: nz.min() would raise ValueError
    # on the zero-size array — burstiness of nothing is 0, not a crash
    burstiness = float(counts.max() / max(nz.min(), 1.0)) if nz.size else 0.0
    return {"mean_rate": float(counts.mean()),
            "max_rate": float(counts.max()),
            "min_rate": float(counts.min()),
            "burstiness": burstiness}
