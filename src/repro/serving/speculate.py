"""Draft proposal for n-gram speculative decoding.

The paged engine verifies K drafted tokens per decode row in ONE ragged
C=K+1 `paged_step` chunk (serving/engine.py `_spec_fn`), so all the
"speculation" that happens here is host-side string matching over token
ids — there is no second model, no extra dispatch, and nothing on this
path may touch a device array (the proposer is a repro-lint hot root:
a host sync here would serialize every decode step).

The proposer is prompt-lookup decoding (arXiv 2304.04487 / 2311.08252
lineage): LLM output is self-similar — retrieval answers quote the
prompt, code repeats identifiers, chat repeats phrasing — so the most
recent occurrence of the current suffix n-gram in the request's own
history (prompt + generated tokens) is a cheap, surprisingly accurate
predictor of what comes next. Greedy verification then makes the
emitted stream BIT-IDENTICAL to non-speculative decoding: drafts only
ever decide how many tokens one dispatch confirms, never which tokens.
"""

from __future__ import annotations

from repro.core.policy import SpeculationConfig


class NgramProposer:
    """Suffix n-gram matcher over a request's own token history.

    `propose` tries the longest configured suffix first (`ngram_max`
    down to `ngram_min`): find the most recent EARLIER occurrence of
    the history's n-token suffix and return the up-to-`k` tokens that
    followed it. No match at any n returns [] — the engine then runs
    that row as a plain C=1 decode, so a cold (non-repetitive) stream
    costs nothing beyond this scan.

    Pure host-side Python over int lists, O(ngram_max * len(history))
    per row worst case; `propose` is registered with repro-lint's
    hot-root sweep and must stay free of device work.
    """

    def __init__(self, cfg: SpeculationConfig | None = None):
        self.cfg = cfg or SpeculationConfig()

    def propose(self, history: list[int], k: int) -> list[int]:
        """Draft up to `k` tokens following `history` (prompt + output
        so far, most recent last); [] if no suffix n-gram recurs earlier
        in the history.

        Selection order: the most recent match of the LONGEST suffix
        n-gram whose continuation is a full k tokens (recent repetitions
        best reflect current phrasing); when every match of every n sits
        too close to the history's end for that — the short-history
        pure-loop case — fall back to the longest continuation seen, so
        a tight repetition cycle still drafts the whole loop instead of
        its final token."""
        if k <= 0:
            return []
        cfg = self.cfg
        h = history
        n_hist = len(h)
        best: list[int] = []
        for n in range(min(cfg.ngram_max, n_hist - 1), cfg.ngram_min - 1, -1):
            suffix = h[n_hist - n:]
            # scan backward over candidate match *ends*: most recent first
            for end in range(n_hist - 1, n - 1, -1):
                if h[end - n:end] == suffix:
                    cand = h[end:end + k]
                    if end + k <= n_hist:
                        return cand
                    if len(cand) > len(best):
                        best = cand
                    # earlier matches have longer continuations — keep
                    # scanning before settling for a truncated draft
        return best
