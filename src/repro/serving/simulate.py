"""Discrete-event serving simulation with a roofline-calibrated cost model
(reproduces paper Fig. 1b: FP16 vs FP8 vs dual-precision SLO compliance).

Wall-clock cannot be measured on CPU, so iteration latency comes from a
cost model calibrated against the dry-run roofline terms (or the paper's
measured H100 numbers for its models): a serving iteration costs

    step_ms(mode) = fixed + weight_ms(mode) + kv_ms + compute_ms(mode)·tokens

with weight traffic halved and MXU rate doubled in FP8 mode — exactly the
two effects NestedFP unlocks (paper §4.1). The simulator replays a trace
through the same continuous-batching scheduler + DualPrecisionController
as the real engine and reports p90 TPOT / TTFT, SLO-violation seconds,
and the fraction of time served at FP16.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.policy import DualPrecisionController, SLOConfig, StepObservation
from repro.serving.trace import TraceRequest


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-iteration latency model (ms)."""
    fixed_ms: float = 2.0
    weight_read_ms_fp16: float = 10.0      # params x 2B / HBM_bw
    weight_read_ms_fp8: float = 5.0        # upper byte only: half traffic
    kv_ms_per_ktoken: float = 0.02         # cache read per 1k cached tokens
    compute_ms_per_token_fp16: float = 0.05
    compute_ms_per_token_fp8: float = 0.025

    @classmethod
    def from_model(cls, n_params: float, *, hbm_bw: float = 819e9,
                   peak_flops: float = 197e12, n_chips: int = 1,
                   kv_bytes_per_token: float = 0.0) -> "CostModel":
        w16 = n_params * 2 / (hbm_bw * n_chips) * 1e3
        c16 = 2 * n_params / (peak_flops * n_chips) * 1e3
        kv = kv_bytes_per_token * 1000 / (hbm_bw * n_chips) * 1e3
        return cls(fixed_ms=2.0, weight_read_ms_fp16=w16,
                   weight_read_ms_fp8=w16 / 2, kv_ms_per_ktoken=kv,
                   compute_ms_per_token_fp16=c16,
                   compute_ms_per_token_fp8=c16 / 2)

    def step_ms(self, mode: str, decode_tokens: int, prefill_tokens: int,
                cached_ktokens: float) -> float:
        if mode == "fp16":
            w, c = self.weight_read_ms_fp16, self.compute_ms_per_token_fp16
        else:
            w, c = self.weight_read_ms_fp8, self.compute_ms_per_token_fp8
        tokens = decode_tokens + prefill_tokens
        # weight read is amortized across the batch (one pass per step)
        return (self.fixed_ms + w + self.kv_ms_per_ktoken * cached_ktokens
                + c * tokens)


@dataclasses.dataclass
class SimResult:
    policy: str
    p50_tpot_ms: float
    p90_tpot_ms: float
    p99_tpot_ms: float
    p90_ttft_ms: float
    slo_violation_s: float
    duration_s: float
    fp16_fraction: float
    n_finished: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


def simulate(reqs: list[TraceRequest], cost: CostModel, *,
             policy: str = "dual", slo: SLOConfig | None = None,
             max_batch: int = 64, duration_s: float | None = None
             ) -> SimResult:
    """policy: 'fp16' | 'fp8' | 'dual' (controller-driven)."""
    slo = slo or SLOConfig()
    controller = DualPrecisionController(
        slo,
        fp16_ms_per_token=cost.compute_ms_per_token_fp16,
        fp8_ms_per_token=cost.compute_ms_per_token_fp8,
        fixed_overhead_ms=cost.fixed_ms + cost.weight_read_ms_fp16)

    queue: list[TraceRequest] = []
    pending = sorted(reqs, key=lambda r: r.arrival_s)
    active: list[dict] = []
    now = 0.0
    tpots: list[float] = []
    ttfts: list[float] = []
    viol_time = 0.0
    mode_time = {"fp16": 0.0, "fp8": 0.0}
    finished = 0
    last_ms = None

    while pending or queue or active:
        while pending and pending[0].arrival_s <= now:
            queue.append(pending.pop(0))
        # admit
        prefill_tokens = 0
        while queue and len(active) < max_batch:
            r = queue.pop(0)
            active.append({"req": r, "left": r.max_new, "cached": r.prompt_len,
                           "first": True})
            prefill_tokens += r.prompt_len
        if not active:
            if pending:
                now = max(now, pending[0].arrival_s)
                continue
            break
        # precision decision
        batch_tokens = prefill_tokens + len(active)
        if policy == "dual":
            mode = controller.decide(StepObservation(
                batch_tokens=batch_tokens, queue_depth=len(queue),
                measured_step_ms=last_ms))
        else:
            mode = policy
        cached_k = sum(a["cached"] for a in active) / 1000.0
        step = cost.step_ms(mode, len(active), prefill_tokens, cached_k)
        last_ms = step
        now += step / 1000.0
        mode_time[mode] += step / 1000.0
        if step > slo.tpot_ms:
            viol_time += step / 1000.0
        # token bookkeeping
        done = []
        for a in active:
            a["cached"] += 1
            a["left"] -= 1
            if a["first"]:
                ttfts.append((now - a["req"].arrival_s) * 1000.0)
                a["first"] = False
            else:
                tpots.append(step)
            if a["left"] <= 0:
                done.append(a)
        for a in done:
            active.remove(a)
            finished += 1

    tp = np.asarray(tpots) if tpots else np.asarray([0.0])
    tt = np.asarray(ttfts) if ttfts else np.asarray([0.0])
    total = sum(mode_time.values()) or 1.0
    return SimResult(
        policy=policy,
        p50_tpot_ms=float(np.percentile(tp, 50)),
        p90_tpot_ms=float(np.percentile(tp, 90)),
        p99_tpot_ms=float(np.percentile(tp, 99)),
        p90_ttft_ms=float(np.percentile(tt, 90)),
        slo_violation_s=viol_time,
        duration_s=now,
        fp16_fraction=mode_time["fp16"] / total,
        n_finished=finished,
    )
