"""KV-cache managers + per-family cache descriptors for the engine.

Every serving-relevant architecture is described by a `CacheDescriptor`:
a set of token-granular PAGED planes (block-pooled, managed by
`BlockManager`) plus, for recurrent families, SLOT-RESIDENT planes
(fixed per-sequence state with no token axis, tracked by `SlotManager`).
The four descriptor kinds:

* `gqa`    — K/V pairs per layer (optionally byte-planar NestedKV);
             all planes paged.
* `mla`    — DeepSeek latent planes `c_kv` + `k_rope` per layer;
             all planes paged (576 f16 values/token for deepseek-v3).
* `hybrid` — zamba2-class: the shared-attention K/V planes are paged
             (one logical layer per application group) while the
             Mamba2 conv + SSD state is slot-resident.
* `ssm`    — pure Mamba2: slot-resident state only; block tables
             degenerate to token-length accounting.

`BlockManager` is the paged side (the paper's §3.3 serving story: KV
memory bounds the admissible batch, so reserving `capacity` tokens per
slot wastes exactly the HBM that NestedFP's zero-overhead weights
reclaim). Physical KV lives in a pool of fixed-size token blocks; each
sequence owns an ordered block table and grows one block at a time.
Admission is driven by free blocks, not free slots, and when blocks run
out the youngest sequence is preempted (blocks released, request
recomputed later — vLLM-style recompute preemption). Because MLA latent
and hybrid shared-attention blocks live in the same pool abstraction,
the controller's `free_block_frac` memory-pressure trigger sees
deepseek/zamba-class sequences exactly like GQA ones.

`SlotManager` is the slot-resident side of the `hybrid`/`ssm`
descriptors: one state slot per sequence, claimed in lockstep with the
BlockManager slot index (`claim`), zeroed at (re-)admission. The legacy
fixed-slot ENGINE path that used it for whole KV caches is retired —
every family now schedules through the paged path.

Physical block 0 is reserved as a trash block: jit'd steps always write
a full (possibly padded) chunk, and pad/inactive-row writes are pointed
at block 0 so they can never clobber live cache state.

Copy-on-write prefix caching (`prefix_cache=True`)
--------------------------------------------------
Every physical block carries a REFCOUNT, and every FULL block whose
content has been committed is registered in a content-hash index keyed
by a prefix chain hash `h_i = hash((h_{i-1}, tokens_of_block_i))` — a
block's identity is the whole token prefix up to and including it, so
identical (system-prompt / few-shot / replayed-after-preemption)
prefixes map to identical chains. At admission `attach_prefix` walks a
new sequence's chain and shares the longest run of cached blocks
(incref, zero recompute, zero new HBM). Sharing is read-only: the engine
always recomputes at least the last prompt token so the first-token
logit exists, and `cow_for_write` forks any write-target block whose
refcount exceeds one (allocate, copy bytes, decref the shared original)
before the write lands — writers can never clobber a neighbour's prefix.

Releasing a sequence (retire OR preempt) decrefs its blocks; registered
blocks whose refcount hits zero are parked in an LRU pool of
unreferenced-but-cached blocks instead of the free list. The allocator
reclaims LRU blocks (evicting their index entries) only after the free
list runs dry, so cached prefixes survive exactly as long as the pool
has headroom and reclaim always happens BEFORE preemption would: a
sequence is only ever preempted for blocks that live sequences hold.

Block identity is token-based, not byte-based: under the dual-precision
controller a reused block may have been written in either precision —
interchangeable by construction in NestedFP's serving model (both modes
read the same nested buffers). Forced-mode runs are bit-exact.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


# ---------------------------------------------------------------------------
# cache descriptors (per-family layouts; factory: models/model.py
# `cache_descriptor(cfg)`)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """One token-granular cache plane, paged into fixed-size blocks.

    A pool leaf is shaped (n_layers, n_total_blocks, block_size,
    *token_shape); `token_shape` is the per-token feature shape (GQA:
    (Hkv, Hd); MLA c_kv: (kv_lora_rank,))."""
    name: str
    n_layers: int
    token_shape: tuple[int, ...]
    dtype: str                          # numpy dtype name

    @property
    def bytes_per_token(self) -> int:
        return int(self.n_layers * np.prod(self.token_shape, dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class SlotPlaneSpec:
    """Slot-resident (non-paged) state: one fixed-shape entry per
    sequence slot, no token axis. A pool leaf is shaped
    (shape[0], n_slots, *shape[1:]) — batch rides axis 1, matching the
    layer-stacked cache convention."""
    name: str
    shape: tuple[int, ...]              # per-slot shape incl. layer dim
    dtype: str

    @property
    def bytes_per_slot(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class CacheDescriptor:
    """Per-family cache layout: which planes are paged (BlockManager)
    and which are slot-resident (SlotManager). `prefix_cacheable` is
    False for recurrent families — a cached KV prefix cannot stand in
    for slot-resident SSM state, so sharing blocks would skip state
    recomputation."""
    kind: str                           # "gqa" | "mla" | "hybrid" | "ssm"
    planes: tuple[PlaneSpec, ...] = ()
    slot_planes: tuple[SlotPlaneSpec, ...] = ()
    prefix_cacheable: bool = True

    @property
    def paged(self) -> bool:
        return bool(self.planes)

    @property
    def bytes_per_token(self) -> int:
        """Paged-plane bytes per cached token (0 for pure SSM)."""
        return sum(p.bytes_per_token for p in self.planes)

    def bytes_per_block(self, block_size: int) -> int:
        return self.bytes_per_token * block_size

    @property
    def bytes_per_slot(self) -> int:
        """Slot-resident state bytes per sequence (0 for gqa/mla)."""
        return sum(p.bytes_per_slot for p in self.slot_planes)


@dataclasses.dataclass
class Slot:
    request_id: str | None = None
    length: int = 0
    max_new: int = 0
    generated: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, n_slots: int, capacity: int):
        self.n_slots = n_slots
        self.capacity = capacity
        self.slots = [Slot() for _ in range(n_slots)]

    def try_allocate(self, request_id: str, prompt_len: int,
                     max_new: int) -> int | None:
        if prompt_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {prompt_len}+{max_new} exceeds "
                f"slot capacity {self.capacity}")
        for i, s in enumerate(self.slots):
            if s.free:
                self.slots[i] = Slot(request_id, prompt_len, max_new, 0)
                return i
        return None

    def claim(self, idx: int, request_id: str, prompt_len: int,
              max_new: int) -> None:
        """Claim a SPECIFIC slot — used by the engine to keep the
        slot-resident state side of a hybrid/ssm descriptor in lockstep
        with the BlockManager's slot assignment."""
        assert self.slots[idx].free, f"slot {idx} already claimed"
        self.slots[idx] = Slot(request_id, prompt_len, max_new, 0)

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def n_free(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def utilization(self) -> float:
        used = sum(s.length for s in self.slots if not s.free)
        return used / (self.n_slots * self.capacity)


TRASH_BLOCK = 0


def _chain_hash(parent: int, tokens: tuple[int, ...]) -> int:
    return hash((parent, tokens))


_ROOT_HASH = hash(("prefix-root",))


@dataclasses.dataclass
class _Seq:
    request_id: str
    blocks: list[int]          # physical block ids, logical order
    length: int = 0            # tokens committed to the cache
    admitted: int = 0          # admission counter (largest == youngest)
    hashes: list[int] = dataclasses.field(default_factory=list)
    # chain hashes of the committed full-block prefix (len == number of
    # full blocks already registered/matched for this sequence)


class BlockManager:
    """Free-list allocator of fixed-size KV blocks with per-sequence
    block tables, per-block refcounts, and (optionally) copy-on-write
    prefix caching (see module docstring for the COW design).

    `n_blocks` counts USABLE blocks; physical block 0 (trash) is extra,
    so pools must be allocated with `n_total_blocks` blocks. Unassigned
    block-table entries point at the trash block — reads through them
    are masked by per-row lengths, writes land in garbage space.

    A persistent `(n_slots, max_blocks_per_seq)` int32 table array is
    maintained incrementally by ensure/attach/fork/release — `tables()`
    is O(1) per decode step instead of a full Python rebuild.
    """

    def __init__(self, n_slots: int, block_size: int, n_blocks: int,
                 max_blocks_per_seq: int, prefix_cache: bool = False):
        assert block_size > 0 and n_blocks > 0
        self.n_slots = n_slots
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefix_cache = prefix_cache
        # pop() hands out low block ids first (deterministic layouts in tests)
        self._free = list(range(n_blocks, 0, -1))
        self.seqs: list[_Seq | None] = [None] * n_slots
        self._admissions = 0
        self._ref = [0] * (n_blocks + 1)             # per-physical refcount
        self._index: dict[int, int] = {}             # chain hash -> block id
        self._hash_of: dict[int, int] = {}           # registered block -> hash
        self._lru: collections.OrderedDict[int, None] = collections.OrderedDict()
        # unreferenced-but-cached blocks, least recently used first
        self._tables = np.full((n_slots, max_blocks_per_seq), TRASH_BLOCK,
                               np.int32)
        self.prefix_stats = {"queries": 0, "lookup_tokens": 0,
                             "hit_tokens": 0, "blocks_shared": 0,
                             "cow_forks": 0, "evictions": 0}

    # -- pool-level views ------------------------------------------------------
    @property
    def n_total_blocks(self) -> int:
        return self.n_blocks + 1                     # + trash block 0

    @property
    def capacity(self) -> int:
        """Max tokens a single sequence can hold."""
        return self.max_blocks_per_seq * self.block_size

    def n_free_blocks(self) -> int:
        """Allocatable blocks: truly free + reclaimable LRU-cached."""
        return len(self._free) + len(self._lru)

    def n_cached_blocks(self) -> int:
        """Unreferenced blocks kept warm in the prefix cache."""
        return len(self._lru)

    def n_free_slots(self) -> int:
        return sum(1 for s in self.seqs if s is None)

    def blocks_in_use(self) -> int:
        """Blocks referenced by live sequences (shared blocks count once)."""
        return self.n_blocks - self.n_free_blocks()

    def utilization(self) -> float:
        return self.blocks_in_use() / self.n_blocks

    def free_block_frac(self) -> float:
        """Allocatable fraction of the pool — the MorphServe-style
        memory-pressure signal fed to the dual-precision controller."""
        return self.n_free_blocks() / self.n_blocks

    def table(self, idx: int):
        """(max_blocks_per_seq,) int32 block table for one slot; holes
        point at the trash block. A view into the persistent table —
        valid until the next ensure/fork/release on this slot."""
        return self._tables[idx]

    def tables(self):
        """(n_slots, max_blocks_per_seq) persistent int32 table array
        (maintained incrementally; do not mutate)."""
        return self._tables

    # -- allocation core -------------------------------------------------------
    def _alloc_block(self) -> int | None:
        """Pop a free block; when the free list is dry, reclaim the
        least-recently-used cached block (evicting its index entry) —
        cached prefixes are always sacrificed before preemption is."""
        if self._free:
            return self._free.pop()
        if self._lru:
            b, _ = self._lru.popitem(last=False)
            h = self._hash_of.pop(b)
            del self._index[h]
            self.prefix_stats["evictions"] += 1
            return b
        return None

    def _release_block(self, b: int) -> None:
        """Decref; park registered zero-ref blocks in the LRU cache,
        return unregistered ones to the free list."""
        self._ref[b] -= 1
        assert self._ref[b] >= 0, f"refcount underflow on block {b}"
        if self._ref[b] == 0:
            if b in self._hash_of:
                self._lru[b] = None          # most-recent end
            else:
                self._free.append(b)

    # -- sequence lifecycle ----------------------------------------------------
    def try_allocate(self, request_id: str, seq_len: int, max_new: int,
                     cached_blocks: int = 0) -> int | None:
        """Claim a slot for a sequence (no blocks yet — `ensure` grows
        them chunk by chunk). None when no slot is free or when the
        first chunk could not possibly be admitted (fewer free blocks
        than the whole prompt needs — the admission watermark that keeps
        preemption for decode-time growth, not thrashing admissions).
        `cached_blocks` discounts prefix-cache hits from that watermark:
        matched blocks cost nothing to re-establish."""
        if seq_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {seq_len}+{max_new} exceeds paged "
                f"capacity {self.capacity}")
        if -(-(seq_len + max_new) // self.block_size) > self.n_blocks:
            raise ValueError(
                f"request {request_id}: needs more blocks than the whole "
                f"pool holds ({self.n_blocks}) — would preempt-thrash forever")
        need = -(-max(seq_len, 1) // self.block_size) - cached_blocks
        if need > self.n_free_blocks():
            return None
        for i, s in enumerate(self.seqs):
            if s is None:
                self._admissions += 1
                self.seqs[i] = _Seq(request_id, [], 0, self._admissions)
                return i
        return None

    def ensure(self, idx: int, n_tokens: int) -> bool:
        """Grow slot `idx`'s block table to cover positions [0, n_tokens).
        All-or-nothing; False when the free list (including reclaimable
        cached blocks) runs dry (caller preempts or defers)."""
        seq = self.seqs[idx]
        assert seq is not None, idx
        need = -(-n_tokens // self.block_size) - len(seq.blocks)
        if need <= 0:
            return True
        if n_tokens > self.capacity or need > self.n_free_blocks():
            return False
        for _ in range(need):
            b = self._alloc_block()
            assert b is not None          # guarded by n_free_blocks above
            self._ref[b] = 1
            self._tables[idx, len(seq.blocks)] = b
            seq.blocks.append(b)
        return True

    def set_length(self, idx: int, n_tokens: int) -> None:
        seq = self.seqs[idx]
        assert seq is not None and n_tokens <= len(seq.blocks) * self.block_size
        seq.length = n_tokens

    def release(self, idx: int) -> None:
        """Decref (not free) every block the sequence holds — shared
        blocks survive for their other holders, registered blocks go to
        the LRU cache."""
        seq = self.seqs[idx]
        if seq is None:
            return
        for b in reversed(seq.blocks):
            self._release_block(b)
        self._tables[idx, :] = TRASH_BLOCK
        self.seqs[idx] = None

    def youngest(self) -> int | None:
        """Slot of the most recently admitted live sequence (the
        preemption victim), or None when nothing is live."""
        live = [(s.admitted, i) for i, s in enumerate(self.seqs)
                if s is not None]
        return max(live)[1] if live else None

    # -- prefix caching --------------------------------------------------------
    def _match(self, tokens) -> tuple[list[int], list[int]]:
        """Longest cached full-block chain for `tokens`; returns
        (block ids, chain hashes)."""
        blocks: list[int] = []
        hashes: list[int] = []
        parent = _ROOT_HASH
        bs = self.block_size
        for i in range(len(tokens) // bs):
            h = _chain_hash(parent, tuple(tokens[i * bs: (i + 1) * bs]))
            b = self._index.get(h)
            if b is None:
                break
            blocks.append(b)
            hashes.append(h)
            parent = h
        return blocks, hashes

    def lookup_prefix(self, tokens) -> int:
        """Matched-prefix length in tokens (no side effects)."""
        if not self.prefix_cache:
            return 0
        return len(self._match(tokens)[0]) * self.block_size

    def prefix_admit_discount(self, tokens) -> int:
        """Blocks the admission watermark may discount for `tokens`:
        matched blocks held LIVE by other sequences (sharing them costs
        nothing). Matched blocks parked in the LRU pool are already
        counted by `n_free_blocks()`, so discounting them too would
        double-count."""
        if not self.prefix_cache:
            return 0
        return sum(1 for b in self._match(tokens)[0] if self._ref[b] > 0)

    def attach_prefix(self, idx: int, tokens) -> int:
        """Share the longest cached full-block prefix of `tokens` into
        freshly-allocated slot `idx` (incref each matched block, pull
        zero-ref ones out of the LRU pool). Returns the matched token
        count; the caller starts prefill at that offset (recomputing at
        least one token — `cow_for_write` forks the tail block if that
        recompute lands in a shared one)."""
        seq = self.seqs[idx]
        assert seq is not None and not seq.blocks, "attach before ensure"
        if not self.prefix_cache:
            return 0
        blocks, hashes = self._match(tokens)
        blocks = blocks[: self.max_blocks_per_seq]
        hashes = hashes[: len(blocks)]
        for j, b in enumerate(blocks):
            if self._ref[b] == 0:
                del self._lru[b]
            self._ref[b] += 1
            self._tables[idx, j] = b
        seq.blocks = list(blocks)
        seq.hashes = list(hashes)
        seq.length = len(blocks) * self.block_size
        st = self.prefix_stats
        st["queries"] += 1
        st["lookup_tokens"] += len(tokens)
        st["hit_tokens"] += seq.length
        st["blocks_shared"] += len(blocks)
        return seq.length

    def cow_for_write(self, idx: int, start: int, end: int
                      ) -> list[tuple[int, int]] | None:
        """Copy-on-write fork of every shared block that the token write
        range [start, end) touches: allocate a private replacement,
        decref the shared original, and return (src, dst) pairs whose
        cache bytes the CALLER must copy before writing. Returns None
        when a fork cannot be allocated (pool truly exhausted — caller
        preempts). Blocks must already be ensured over the range."""
        seq = self.seqs[idx]
        assert seq is not None and end <= len(seq.blocks) * self.block_size
        span = range(start // self.block_size, -(-end // self.block_size))
        # all-or-nothing: check every fork is allocatable BEFORE mutating,
        # so a failure never strands completed forks whose (src, dst)
        # pairs the caller would lose (bytes never copied -> stale reads)
        if sum(1 for bi in span if self._ref[seq.blocks[bi]] > 1) \
                > self.n_free_blocks():
            return None
        pairs: list[tuple[int, int]] = []
        for bi in span:
            src = seq.blocks[bi]
            if self._ref[src] <= 1:
                continue
            dst = self._alloc_block()
            assert dst is not None            # guarded above
            self._ref[dst] = 1
            self._release_block(src)
            seq.blocks[bi] = dst
            self._tables[idx, bi] = dst
            pairs.append((src, dst))
            self.prefix_stats["cow_forks"] += 1
        return pairs

    def commit(self, idx: int, n_tokens: int, tokens) -> None:
        """Record that positions [0, n_tokens) now hold the KV of
        `tokens[:n_tokens]`, and register every newly-FULL block in the
        content-hash index so later sequences can share it. `tokens`
        must be the sequence's full committed token stream."""
        self.set_length(idx, n_tokens)
        if not self.prefix_cache:
            return
        seq = self.seqs[idx]
        bs = self.block_size
        parent = seq.hashes[-1] if seq.hashes else _ROOT_HASH
        for bi in range(len(seq.hashes), n_tokens // bs):
            h = _chain_hash(parent, tuple(tokens[bi * bs: (bi + 1) * bs]))
            b = seq.blocks[bi]
            if h not in self._index and b not in self._hash_of:
                self._index[h] = b
                self._hash_of[b] = h
            seq.hashes.append(h)
            parent = h

    # -- invariant audit (tests) ----------------------------------------------
    def check_invariants(self) -> None:
        ref = [0] * (self.n_blocks + 1)
        for s in self.seqs:
            if s is None:
                continue
            for b in s.blocks:
                ref[b] += 1
        assert ref == self._ref, (ref, self._ref)
        free, lru = set(self._free), set(self._lru)
        assert not (free & lru), "block both free and cached"
        for b in range(1, self.n_blocks + 1):
            if self._ref[b] == 0:
                assert (b in free) ^ (b in lru), \
                    f"zero-ref block {b} neither free nor cached (or both)"
            else:
                assert b not in free and b not in lru, \
                    f"live block {b} on the free/cached list"
        assert set(self._hash_of) == set(self._index.values())
        for h, b in self._index.items():
            assert self._hash_of[b] == h
        for i, s in enumerate(self.seqs):
            row = np.full(self.max_blocks_per_seq, TRASH_BLOCK, np.int32)
            if s is not None:
                row[: len(s.blocks)] = s.blocks
            assert (self._tables[i] == row).all(), f"stale table row {i}"
