"""KV-cache managers + per-family cache descriptors for the engine.

Every serving-relevant architecture is described by a `CacheDescriptor`:
a set of token-granular PAGED planes (block-pooled, managed by
`BlockManager`) plus, for recurrent families, SLOT-RESIDENT planes
(fixed per-sequence state with no token axis, tracked by `SlotManager`).
The four descriptor kinds:

* `gqa`    — K/V pairs per layer (optionally byte-planar NestedKV);
             all planes paged.
* `mla`    — DeepSeek latent planes `c_kv` + `k_rope` per layer;
             all planes paged (576 f16 values/token for deepseek-v3).
* `hybrid` — zamba2-class: the shared-attention K/V planes are paged
             (one logical layer per application group) while the
             Mamba2 conv + SSD state is slot-resident.
* `ssm`    — pure Mamba2: slot-resident state only; block tables
             degenerate to token-length accounting.

`BlockManager` is the paged side (the paper's §3.3 serving story: KV
memory bounds the admissible batch, so reserving `capacity` tokens per
slot wastes exactly the HBM that NestedFP's zero-overhead weights
reclaim). Physical KV lives in a pool of fixed-size token blocks; each
sequence owns an ordered block table and grows one block at a time.
Admission is driven by free blocks, not free slots, and when blocks run
out the youngest sequence is preempted (blocks released, request
recomputed later — vLLM-style recompute preemption). Because MLA latent
and hybrid shared-attention blocks live in the same pool abstraction,
the controller's `free_block_frac` memory-pressure trigger sees
deepseek/zamba-class sequences exactly like GQA ones.

`SlotManager` is the slot-resident side of the `hybrid`/`ssm`
descriptors: one state slot per sequence, claimed in lockstep with the
BlockManager slot index (`claim`), zeroed at (re-)admission. The legacy
fixed-slot ENGINE path that used it for whole KV caches is retired —
every family now schedules through the paged path.

Physical block 0 is reserved as a trash block: jit'd steps always write
a full (possibly padded) chunk, and pad/inactive-row writes are pointed
at block 0 so they can never clobber live cache state.

Copy-on-write prefix caching (`prefix_cache=True`)
--------------------------------------------------
Every physical block carries a REFCOUNT, and every FULL block whose
content has been committed is registered in a content-hash index keyed
by a prefix chain hash `h_i = hash((h_{i-1}, tokens_of_block_i))` — a
block's identity is the whole token prefix up to and including it, so
identical (system-prompt / few-shot / replayed-after-preemption)
prefixes map to identical chains. At admission `attach_prefix` walks a
new sequence's chain and shares the longest run of cached blocks
(incref, zero recompute, zero new HBM). Sharing is read-only: the engine
always recomputes at least the last prompt token so the first-token
logit exists, and `cow_for_write` forks any write-target block whose
refcount exceeds one (allocate, copy bytes, decref the shared original)
before the write lands — writers can never clobber a neighbour's prefix.

Releasing a sequence (retire OR preempt) decrefs its blocks; registered
blocks whose refcount hits zero are parked in an LRU pool of
unreferenced-but-cached blocks instead of the free list. The allocator
reclaims LRU blocks (evicting their index entries) only after the free
list runs dry, so cached prefixes survive exactly as long as the pool
has headroom and reclaim always happens BEFORE preemption would: a
sequence is only ever preempted for blocks that live sequences hold.

Block identity is token-based, not byte-based: under the dual-precision
controller a reused block may have been written in either precision —
interchangeable by construction in NestedFP's serving model (both modes
read the same nested buffers). Forced-mode runs are bit-exact.

Sliding-window layer groups (gemma3-style local attention)
----------------------------------------------------------
Descriptors whose architecture interleaves LOCAL (sliding-window) and
GLOBAL attention layers (gemma3's 5:1 pattern) carry `LayerGroup`
metadata splitting the paged planes' layer axis into window groups.
`BlockManager` then keeps ONE block table PER GROUP per sequence, and
each group allocates from its OWN id space over the same physical pool
array: a layer only ever reads/writes its own group's rows of a block,
so block id `b` can be live in the global group and the local group
simultaneously without touching the same bytes — no pool doubling, and
no permanently-dead other-group rows inside an allocated block. A
windowed group's blocks are **slide-freed** the moment they fall fully
out of every future query's window — `slide_window` (invoked on every
`ensure`) decrefs dead local blocks, returns exclusively-held ones
straight to the group's free list, and points the table hole back at
the trash block. Global-group blocks stay pinned for the sequence's
whole life, so `free_block_frac` (the MINIMUM headroom across groups —
the binding constraint) reports the HONEST pressure the dual-precision
controller acts on instead of phantom pressure from dead local-layer
KV.

Slide-freed blocks are evicted from the prefix index at slide time, so
they can never be prefix-matched for local groups; blocks a live
neighbour still shares stay matchable (their content is intact). Prefix
matching itself is GROUP-AWARE (`_match_plan`): a resumable offset `m`
requires the global groups' full [0, m) chain AND, per windowed group,
only the cached blocks covering the resume position's lookback window
[q0 - window + 1, m*bs) — freshly attached sequences therefore start
with their local groups already slid to that point.

Device-resident block tables (`device_tables`)
----------------------------------------------
The engine dispatches one jitted step per iteration; re-uploading the
whole (G, n_slots, MB) table array from host every step would put an
O(table) host→device transfer on the per-step critical path even though
a typical step changes only a handful of entries (one `ensure` append
per growing row, the odd COW fork or window slide). `BlockManager`
therefore keeps a DEVICE mirror of the host table array: every table
mutation is recorded in a dirty set, and `device_tables()` flushes the
accumulated (group, slot, j) -> block updates with ONE small jitted
scatter (update count bucketed to a power of two so the scatter
executable is reused; the old device buffer is donated so the update is
in place, never a pool-sized copy). Steady-state decode uploads a few
dozen bytes per step instead of the full table. The host array stays
the source of truth for all allocator logic and tests.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# cache descriptors (per-family layouts; factory: models/model.py
# `cache_descriptor(cfg)`)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """One token-granular cache plane, paged into fixed-size blocks.

    A pool leaf is shaped (n_layers, n_total_blocks, block_size,
    *token_shape); `token_shape` is the per-token feature shape (GQA:
    (Hkv, Hd); MLA c_kv: (kv_lora_rank,))."""
    name: str
    n_layers: int
    token_shape: tuple[int, ...]
    dtype: str                          # numpy dtype name

    @property
    def bytes_per_token(self) -> int:
        return int(self.n_layers * np.prod(self.token_shape, dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class SlotPlaneSpec:
    """Slot-resident (non-paged) state: one fixed-shape entry per
    sequence slot, no token axis. A pool leaf is shaped
    (shape[0], n_slots, *shape[1:]) — batch rides axis 1, matching the
    layer-stacked cache convention."""
    name: str
    shape: tuple[int, ...]              # per-slot shape incl. layer dim
    dtype: str

    @property
    def bytes_per_slot(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    """One attention-window group of the paged planes' layer axis.

    gemma3-style configs split into a "global" group (window None — keys
    live for the whole sequence) and a "local" group (sliding window in
    tokens — keys die once they fall out of every future query's
    lookback). Each group gets its OWN per-sequence block table in
    `BlockManager`, which is what makes local blocks reclaimable while
    global blocks stay pinned."""
    name: str
    window: int | None                  # tokens; None = full attention
    layers: tuple[int, ...]             # indices into the planes' layer axis


@dataclasses.dataclass(frozen=True)
class CacheDescriptor:
    """Per-family cache layout: which planes are paged (BlockManager)
    and which are slot-resident (SlotManager). `prefix_cacheable` is
    False for recurrent families — a cached KV prefix cannot stand in
    for slot-resident SSM state, so sharing blocks would skip state
    recomputation. `groups` (empty = one implicit global group) carries
    the per-layer-group window metadata for sliding-window archs."""
    kind: str                           # "gqa" | "mla" | "hybrid" | "ssm"
    planes: tuple[PlaneSpec, ...] = ()
    slot_planes: tuple[SlotPlaneSpec, ...] = ()
    prefix_cacheable: bool = True
    groups: tuple[LayerGroup, ...] = ()

    @property
    def group_windows(self) -> tuple[int | None, ...]:
        """Per-group sliding window (None = global); the BlockManager's
        `group_windows` argument. Single implicit global group when the
        descriptor carries no explicit layer groups."""
        if not self.groups:
            return (None,)
        return tuple(g.window for g in self.groups)

    def layer_group_map(self, n_layers: int) -> np.ndarray:
        """(n_layers,) int32 map from plane layer index to group index
        (all zeros for the implicit single global group)."""
        out = np.zeros(n_layers, np.int32)
        if self.groups:
            seen: set[int] = set()
            for gi, g in enumerate(self.groups):
                for li in g.layers:
                    assert 0 <= li < n_layers and li not in seen, (gi, li)
                    seen.add(li)
                    out[li] = gi
            assert len(seen) == n_layers, "layer groups must cover the stack"
        return out

    @property
    def paged(self) -> bool:
        return bool(self.planes)

    @property
    def bytes_per_token(self) -> int:
        """Paged-plane bytes per cached token (0 for pure SSM)."""
        return sum(p.bytes_per_token for p in self.planes)

    def bytes_per_block(self, block_size: int) -> int:
        return self.bytes_per_token * block_size

    @property
    def bytes_per_slot(self) -> int:
        """Slot-resident state bytes per sequence (0 for gqa/mla)."""
        return sum(p.bytes_per_slot for p in self.slot_planes)


@dataclasses.dataclass
class Slot:
    request_id: str | None = None
    length: int = 0
    max_new: int = 0
    generated: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, n_slots: int, capacity: int):
        self.n_slots = n_slots
        self.capacity = capacity
        self.slots = [Slot() for _ in range(n_slots)]

    def try_allocate(self, request_id: str, prompt_len: int,
                     max_new: int) -> int | None:
        if prompt_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {prompt_len}+{max_new} exceeds "
                f"slot capacity {self.capacity}")
        for i, s in enumerate(self.slots):
            if s.free:
                self.slots[i] = Slot(request_id, prompt_len, max_new, 0)
                return i
        return None

    def claim(self, idx: int, request_id: str, prompt_len: int,
              max_new: int) -> None:
        """Claim a SPECIFIC slot — used by the engine to keep the
        slot-resident state side of a hybrid/ssm descriptor in lockstep
        with the BlockManager's slot assignment."""
        assert self.slots[idx].free, f"slot {idx} already claimed"
        self.slots[idx] = Slot(request_id, prompt_len, max_new, 0)

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def n_free(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def utilization(self) -> float:
        used = sum(s.length for s in self.slots if not s.free)
        return used / (self.n_slots * self.capacity)


TRASH_BLOCK = 0


@functools.partial(jax.jit, donate_argnums=(0,))
def _table_scatter(tables, idx, val):
    """Apply K incremental (group, slot, j) -> block updates to the
    device table mirror in place (donated)."""
    return tables.at[idx[:, 0], idx[:, 1], idx[:, 2]].set(val)


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def _chain_hash(parent: int, tokens: tuple[int, ...]) -> int:
    """Stable content digest of one block's prefix chain link.

    Python's builtin `hash()` is salted per process (PYTHONHASHSEED), so
    chain hashes built from it could never be compared across engine
    processes or serialized with the host-tier prefix store — two
    restarts of the same engine would disagree on every key. blake2b
    over the parent digest + the block's token bytes is deterministic
    everywhere, and 64 bits keeps the index keys cheap ints."""
    h = hashlib.blake2b(int(parent).to_bytes(8, "little", signed=True),
                        digest_size=8)
    for t in tokens:
        h.update(int(t).to_bytes(8, "little", signed=True))
    return int.from_bytes(h.digest(), "little", signed=True)


_ROOT_HASH = int.from_bytes(
    hashlib.blake2b(b"prefix-root", digest_size=8).digest(),
    "little", signed=True)


class HostPool:
    """Second KV tier: pinned host memory holding evicted prefix blocks.

    Entries are keyed by (group, chain hash) — the same stable identity
    the device-side prefix index uses — and each holds one block's pool
    bytes per plane, shaped (group_layers, block_size, *token_shape) as
    numpy arrays. The tier is INCLUSIVE: restoring an entry to the
    device keeps the host copy, so a restored-then-re-evicted block
    (registered blocks are immutable under COW) never needs a second
    d2h capture, and lazily-restored lo planes always have a source.

    `max_bytes` bounds the tier with drop-oldest LRU eviction; entries
    some device block still depends on (a queued restore, or a pending
    lazy lo-plane upload) are PINNED and skipped by the eviction scan.
    """

    def __init__(self, max_bytes: int | None = None):
        self.entries: collections.OrderedDict[
            tuple[int, int], dict[str, np.ndarray]] = collections.OrderedDict()
        self.max_bytes = max_bytes
        self.bytes = 0
        self._pins: collections.Counter = collections.Counter()
        self._sums: dict[tuple[int, int], bytes] = {}
        self._corrupt: set[tuple[int, int]] = set()
        self.stats = {"spilled_blocks": 0, "spilled_bytes": 0,
                      "restored_blocks": 0, "restored_bytes": 0,
                      "dropped_blocks": 0, "loaded_blocks": 0,
                      "corrupt_blocks": 0}

    def __contains__(self, key: tuple[int, int]) -> bool:
        return key in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    @staticmethod
    def entry_bytes(planes: dict[str, np.ndarray]) -> int:
        return sum(a.nbytes for a in planes.values())

    def pin(self, key: tuple[int, int]) -> None:
        assert key in self.entries, key
        self._pins[key] += 1

    def unpin(self, key: tuple[int, int]) -> None:
        assert self._pins[key] > 0, key
        self._pins[key] -= 1
        if self._pins[key] == 0:
            del self._pins[key]

    def pinned(self, key: tuple[int, int]) -> bool:
        return self._pins.get(key, 0) > 0

    @staticmethod
    def checksum(planes: dict[str, np.ndarray]) -> bytes:
        """blake2b integrity digest over a block's plane bytes (names
        sorted so the digest is layout-order independent)."""
        h = hashlib.blake2b(digest_size=16)
        for name in sorted(planes):
            h.update(name.encode())
            h.update(np.ascontiguousarray(planes[name]).tobytes())
        return h.digest()

    def put(self, key: tuple[int, int], planes: dict[str, np.ndarray],
            loaded: bool = False) -> None:
        """Insert (or refresh) one block's bytes; `loaded` marks entries
        deserialized from a persisted store rather than spilled live."""
        if key in self.entries:
            self.bytes -= self.entry_bytes(self.entries.pop(key))
        self.entries[key] = planes
        self._sums[key] = self.checksum(planes)
        self._corrupt.discard(key)
        nb = self.entry_bytes(planes)
        self.bytes += nb
        if loaded:
            self.stats["loaded_blocks"] += 1
        else:
            self.stats["spilled_blocks"] += 1
            self.stats["spilled_bytes"] += nb
        self._shrink()

    def get(self, key: tuple[int, int]) -> dict[str, np.ndarray]:
        self.entries.move_to_end(key)        # LRU touch
        return self.entries[key]

    def verify(self, key: tuple[int, int]) -> bool:
        """Re-derive the entry's checksum and compare with the one
        recorded at `put`. A mismatch (bit rot, torn write, injected
        corruption) is remembered and counted exactly once — callers
        treat the entry as absent and fall back to recompute, never
        restoring garbage KV."""
        if key in self._corrupt:
            return False
        if self._sums.get(key) == self.checksum(self.entries[key]):
            return True
        self._corrupt.add(key)
        self.stats["corrupt_blocks"] += 1
        return False

    def discard(self, key: tuple[int, int]) -> None:
        """Drop one entry outright (corrupt payloads; must be unpinned)."""
        assert not self.pinned(key), key
        self.bytes -= self.entry_bytes(self.entries.pop(key))
        self._sums.pop(key, None)
        self._corrupt.discard(key)

    def _shrink(self) -> None:
        if self.max_bytes is None:
            return
        while self.bytes > self.max_bytes:
            victim = next((k for k in self.entries if not self.pinned(k)),
                          None)
            if victim is None:
                return                       # everything left is pinned
            self.bytes -= self.entry_bytes(self.entries.pop(victim))
            self._sums.pop(victim, None)
            self._corrupt.discard(victim)
            self.stats["dropped_blocks"] += 1


@dataclasses.dataclass
class _Group:
    """One window group's view of a sequence: physical block ids by
    logical index (TRASH_BLOCK = slide-freed hole), the chain hashes of
    the committed/matched full-block prefix, and how many leading
    logical blocks the window has slid past."""
    blocks: list[int] = dataclasses.field(default_factory=list)
    hashes: list[int] = dataclasses.field(default_factory=list)
    slid: int = 0


@dataclasses.dataclass
class _Seq:
    request_id: str
    groups: list[_Group]       # one block table per window group
    length: int = 0            # tokens committed to the cache
    admitted: int = 0          # admission counter (largest == youngest)

    # group-0 views: the only group for non-windowed descriptors (and
    # the GLOBAL group for windowed ones) — keeps single-group callers
    # and tests reading seq.blocks/seq.hashes working unchanged
    @property
    def blocks(self) -> list[int]:
        return self.groups[0].blocks

    @property
    def hashes(self) -> list[int]:
        return self.groups[0].hashes


class BlockManager:
    """Free-list allocator of fixed-size KV blocks with per-sequence
    block tables, per-block refcounts, and (optionally) copy-on-write
    prefix caching (see module docstring for the COW design).

    `n_blocks` counts USABLE blocks; physical block 0 (trash) is extra,
    so pools must be allocated with `n_total_blocks` blocks. Unassigned
    block-table entries point at the trash block — reads through them
    are masked by per-row lengths, writes land in garbage space.

    A persistent `(n_slots, max_blocks_per_seq)` int32 table array is
    maintained incrementally by ensure/attach/fork/release — `tables()`
    is O(1) per decode step instead of a full Python rebuild.
    """

    def __init__(self, n_slots: int, block_size: int, n_blocks: int,
                 max_blocks_per_seq: int, prefix_cache: bool = False,
                 group_windows: tuple[int | None, ...] = (None,),
                 mirror_sharding=None, host_pool: HostPool | None = None):
        assert block_size > 0 and n_blocks > 0
        assert group_windows and all(w is None or w > 0 for w in group_windows)
        self.n_slots = n_slots
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefix_cache = prefix_cache
        self.group_windows = tuple(group_windows)
        self.n_groups = len(self.group_windows)
        # PER-GROUP id spaces over one shared pool array: a layer only
        # touches its own group's rows of a block, so the same id can be
        # live in several groups without byte overlap. pop() hands out
        # low block ids first (deterministic layouts in tests).
        self._free = [list(range(n_blocks, 0, -1))
                      for _ in range(self.n_groups)]
        self.seqs: list[_Seq | None] = [None] * n_slots
        self._admissions = 0
        self._ref = [[0] * (n_blocks + 1)            # per-group refcounts
                     for _ in range(self.n_groups)]
        self._index: dict[tuple[int, int], int] = {}
        # (group, chain hash) -> block id; a block's content is only
        # valid for its group's layers
        self._hash_of: dict[tuple[int, int], int] = {}
        # registered (group, block) -> chain hash
        self._lru: list[collections.OrderedDict[int, None]] = [
            collections.OrderedDict() for _ in range(self.n_groups)]
        # per-group unreferenced-but-cached blocks, least recent first
        self._tables = np.full((self.n_groups, n_slots, max_blocks_per_seq),
                               TRASH_BLOCK, np.int32)
        # device mirror of _tables: created on first device_tables() call,
        # then maintained by small jitted scatters of the dirty set.
        # mirror_sharding (a replicated NamedSharding under a serving
        # mesh) commits the first upload onto every shard; the donated
        # scatter then keeps that placement, so per-step flushes stay
        # ONE logical dispatch of O(dirty) entries — never a per-shard
        # re-upload of the table
        self._dev_tables = None
        self.mirror_sharding = mirror_sharding
        self._dirty: dict[tuple[int, int, int], int] = {}
        self.table_h2d_bytes = 0         # bytes shipped host->device
        self.table_flushes = 0           # incremental scatter dispatches
        self.table_updates = 0           # table entries actually flushed
        self.prefix_stats = {"queries": 0, "lookup_tokens": 0,
                             "hit_tokens": 0, "blocks_shared": 0,
                             "cow_forks": 0, "evictions": 0,
                             "host_hit_blocks": 0}
        self.window_freed_blocks = 0     # blocks returned by window slides
        # ---- tiered KV (HostPool docstring) ----------------------------
        # host: the second tier; None disables spilling entirely.
        # _spill_queue: (group, block, hash) of LRU-evicted registered
        #   blocks whose bytes must be captured to the host tier BEFORE
        #   the next cache-writing dispatch lands (the evicted id is
        #   already reallocated — its bytes are intact only until then).
        # restore_jobs: (group, dst block, hash, ticket) uploads the
        #   engine drains through the scatter path under the SLO guard.
        # _unrestored: (group, block) -> ticket for device blocks whose
        #   bytes have NOT arrived yet; rows holding one are gated out
        #   of chunk scheduling, and a stale ticket voids the job.
        # _lo_pending: (group, block) -> hash for planar blocks whose
        #   fp8 hi planes were restored eagerly but whose lo planes wait
        #   for the first FP16-mode touch (host entry stays pinned).
        self.host = host_pool if prefix_cache else None
        self._spill_queue: list[tuple[int, int, int]] = []
        self._spill_pending: set[tuple[int, int]] = set()
        self.restore_jobs: collections.deque[tuple[int, int, int, int]] = \
            collections.deque()
        self._unrestored: dict[tuple[int, int], int] = {}
        self._lo_pending: dict[tuple[int, int], int] = {}
        self._ticket = 0

    # -- pool-level views ------------------------------------------------------
    @property
    def n_total_blocks(self) -> int:
        return self.n_blocks + 1                     # + trash block 0

    @property
    def capacity(self) -> int:
        """Max tokens a single sequence can hold."""
        return self.max_blocks_per_seq * self.block_size

    def free_blocks(self, group: int) -> int:
        """Allocatable blocks in one group's id space: truly free +
        reclaimable LRU-cached."""
        return len(self._free[group]) + len(self._lru[group])

    def n_free_blocks(self) -> int:
        """Allocatable blocks of the TIGHTEST group — the binding
        constraint on any allocation (every group must be able to cover
        a new block). Identical to the per-pool count for non-windowed
        (single-group) managers."""
        return min(self.free_blocks(g) for g in range(self.n_groups))

    def n_cached_blocks(self) -> int:
        """Unreferenced blocks kept warm in the prefix cache (summed
        over groups)."""
        return sum(len(l) for l in self._lru)

    def n_free_slots(self) -> int:
        return sum(1 for s in self.seqs if s is None)

    def blocks_in_use(self) -> int:
        """Group-blocks referenced by live sequences, summed over
        groups (shared blocks count once per group)."""
        return sum(self.n_blocks - self.free_blocks(g)
                   for g in range(self.n_groups))

    def utilization(self) -> float:
        return self.blocks_in_use() / (self.n_blocks * self.n_groups)

    def free_block_frac(self) -> float:
        """Allocatable fraction of the TIGHTEST group's pool — the
        MorphServe-style memory-pressure signal fed to the
        dual-precision controller. With window reclamation the local
        group keeps returning dead blocks, so this reflects honest
        headroom rather than phantom pressure."""
        return self.n_free_blocks() / self.n_blocks

    def table(self, idx: int, group: int = 0):
        """(max_blocks_per_seq,) int32 block table for one slot and
        window group; holes point at the trash block. A view into the
        persistent table — valid until the next
        ensure/attach/fork/slide/release on this slot."""
        return self._tables[group, idx]

    def tables(self):
        """(n_slots, max_blocks_per_seq) persistent int32 table array
        for group 0 — the only group for non-windowed descriptors
        (maintained incrementally; do not mutate). Windowed managers
        should use `group_tables()`."""
        return self._tables[0]

    def group_tables(self):
        """(n_groups, n_slots, max_blocks_per_seq) persistent int32
        table array — one table per window group, all maintained
        incrementally (do not mutate). `paged_step` gathers each
        layer's KV through its group's table."""
        return self._tables

    def _set_table(self, g: int, idx: int, j: int, b: int) -> None:
        """Single point of mutation for table entries: updates the host
        array and records the entry in the device mirror's dirty set."""
        if self._tables[g, idx, j] != b:
            self._tables[g, idx, j] = b
            if self._dev_tables is not None:
                self._dirty[(g, idx, j)] = int(b)

    def device_tables(self):
        """(n_groups, n_slots, max_blocks_per_seq) int32 DEVICE-resident
        table array. The first call uploads the full host array; every
        later call flushes only the entries mutated since the previous
        flush, as one jitted scatter whose update count is bucketed to a
        power of two (padding repeats the last update, which is
        idempotent) so a handful of executables serve every step. The
        returned array is the engine's per-step `block_tables` argument
        — identical in content to `group_tables()`, with h2d traffic
        proportional to the CHANGE, not the table."""
        if self._dev_tables is None:
            if self.mirror_sharding is not None:
                self._dev_tables = jax.device_put(self._tables,
                                                  self.mirror_sharding)
            else:
                self._dev_tables = jnp.asarray(self._tables)
            self.table_h2d_bytes += self._tables.nbytes
            self.table_flushes += 1
            return self._dev_tables
        if self._dirty:
            k = len(self._dirty)
            kb = _pow2(k)
            idx = np.empty((kb, 3), np.int32)
            val = np.empty((kb,), np.int32)
            for i, ((g, s, j), b) in enumerate(self._dirty.items()):
                idx[i] = (g, s, j)
                val[i] = b
            idx[k:] = idx[k - 1]
            val[k:] = val[k - 1]
            self._dev_tables = _table_scatter(
                self._dev_tables, jnp.asarray(idx), jnp.asarray(val))
            self.table_h2d_bytes += idx.nbytes + val.nbytes
            self.table_flushes += 1
            self.table_updates += k
            self._dirty.clear()
        return self._dev_tables

    # -- allocation core -------------------------------------------------------
    def _alloc_block(self, g: int) -> int | None:
        """Pop a free block from group g's id space; when the free list
        is dry, reclaim the least-recently-used cached block (evicting
        its index entry) — cached prefixes are always sacrificed before
        preemption is."""
        if self._free[g]:
            return self._free[g].pop()
        if self._lru[g]:
            b, _ = self._lru[g].popitem(last=False)
            h = self._hash_of.pop((g, b))
            del self._index[(g, h)]
            self.prefix_stats["evictions"] += 1
            if self.host is not None:
                # spill instead of discard: queue a d2h capture of the
                # block's bytes (drained by the engine before the next
                # cache-writing dispatch). Blocks already mirrored in
                # the host tier — including lazily-pending lo planes,
                # whose DEVICE lo bytes are garbage — skip the capture:
                # the tier is inclusive, the host copy is the truth.
                lo = self._lo_pending.pop((g, b), None)
                if lo is not None:
                    self.host.unpin((g, lo))
                if (g, h) in self.host or (g, h) in self._spill_pending:
                    pass
                else:
                    self._spill_queue.append((g, b, h))
                    self._spill_pending.add((g, h))
            return b
        return None

    def _release_block(self, g: int, b: int) -> None:
        """Decref; park registered zero-ref blocks in the group's LRU
        cache, return unregistered ones to the group's free list. A
        zero-ref block whose restore never completed holds garbage
        bytes — it is deregistered and FREED (its restore job is voided
        by the ticket check), never parked as matchable content."""
        self._ref[g][b] -= 1
        assert self._ref[g][b] >= 0, f"refcount underflow on block {g}/{b}"
        if self._ref[g][b] == 0:
            if (g, b) in self._unrestored:
                self._forget_restore(g, b)
                h = self._hash_of.pop((g, b), None)
                if h is not None:
                    del self._index[(g, h)]
                self._free[g].append(b)
            elif (g, b) in self._hash_of:
                self._lru[g][b] = None       # most-recent end
            else:
                self._free[g].append(b)

    # -- sequence lifecycle ----------------------------------------------------
    def _group_need(self, seq_len: int, window: int | None) -> int:
        """Blocks one group must hold live for a `seq_len`-token
        sequence: the full logical coverage for global groups, only the
        lookback-window span for windowed ones (everything earlier is
        slide-freed by the time the sequence reaches that length)."""
        nb = -(-max(seq_len, 1) // self.block_size)
        if not window:
            return nb
        q0 = max(seq_len - 1, 0)
        return nb - max(0, (q0 - window + 1) // self.block_size)

    def try_allocate(self, request_id: str, seq_len: int, max_new: int,
                     cached_blocks=0) -> int | None:
        """Claim a slot for a sequence (no blocks yet — `ensure` grows
        them chunk by chunk). None when no slot is free or when the
        first chunk could not possibly be admitted (some window group
        has fewer free blocks than the whole prompt needs in ITS id
        space — the admission watermark that keeps preemption for
        decode-time growth, not thrashing admissions). `cached_blocks`
        discounts prefix-cache hits from that watermark — an int
        (applied to every group) or a per-group sequence as returned by
        `prefix_admit_discount`: matched blocks cost nothing to
        re-establish."""
        if seq_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {seq_len}+{max_new} exceeds paged "
                f"capacity {self.capacity}")
        if any(self._group_need(seq_len + max_new, w) > self.n_blocks
               for w in self.group_windows):
            raise ValueError(
                f"request {request_id}: needs more blocks than a whole "
                f"group pool holds ({self.n_blocks}) — would "
                f"preempt-thrash forever")
        if isinstance(cached_blocks, int):
            cached_blocks = (cached_blocks,) * self.n_groups
        if any(self._group_need(seq_len, w) - c > self.free_blocks(g)
               for g, (w, c) in enumerate(zip(self.group_windows,
                                              cached_blocks))):
            return None
        for i, s in enumerate(self.seqs):
            if s is None:
                self._admissions += 1
                self.seqs[i] = _Seq(
                    request_id, [_Group() for _ in self.group_windows],
                    0, self._admissions)
                return i
        return None

    def slide_window(self, idx: int) -> int:
        """Free every windowed-group block that has slid fully out of
        the lookback window of all FUTURE queries (the next query sits
        at `seq.length`, so positions <= length - window are dead).
        Exclusively-held dead blocks go straight back to the free list
        — and are EVICTED from the prefix index, so a slide-freed block
        can never be prefix-matched for a local group again; blocks a
        neighbour still shares are merely decref'd (their content is
        intact for that holder). Returns the number of blocks freed.
        Invoked by `ensure`/`max_coverable` so reclamation happens
        before any allocation decision."""
        seq = self.seqs[idx]
        assert seq is not None, idx
        freed = 0
        for gi, (g, w) in enumerate(zip(seq.groups, self.group_windows)):
            if not w:
                continue
            sp = min(max(0, (seq.length - w + 1) // self.block_size),
                     len(g.blocks))
            for j in range(g.slid, sp):
                b = g.blocks[j]
                if b == TRASH_BLOCK:
                    continue
                self._ref[gi][b] -= 1
                assert self._ref[gi][b] >= 0, \
                    f"refcount underflow on block {gi}/{b}"
                if self._ref[gi][b] == 0:
                    if (gi, b) in self._unrestored:
                        self._forget_restore(gi, b)
                    lo = self._lo_pending.pop((gi, b), None)
                    if lo is not None:
                        self.host.unpin((gi, lo))
                    h = self._hash_of.pop((gi, b), None)
                    if h is not None:
                        del self._index[(gi, h)]
                    self._free[gi].append(b)
                    freed += 1
                g.blocks[j] = TRASH_BLOCK
                self._set_table(gi, idx, j, TRASH_BLOCK)
            g.slid = max(g.slid, sp)
        self.window_freed_blocks += freed
        return freed

    def ensure(self, idx: int, n_tokens: int) -> bool:
        """Grow slot `idx`'s block tables (every window group) to cover
        positions [0, n_tokens), sliding windowed groups first so dead
        local blocks fund the growth. All-or-nothing; False when the
        free list (including reclaimable cached blocks) runs dry
        (caller preempts or defers)."""
        seq = self.seqs[idx]
        assert seq is not None, idx
        self.slide_window(idx)
        nb = -(-n_tokens // self.block_size)
        if all(len(g.blocks) >= nb for g in seq.groups):
            return True
        if n_tokens > self.capacity or any(
                max(0, nb - len(g.blocks)) > self.free_blocks(gi)
                for gi, g in enumerate(seq.groups)):
            return False
        for gi, g in enumerate(seq.groups):
            while len(g.blocks) < nb:
                b = self._alloc_block(gi)
                assert b is not None      # guarded by free_blocks above
                self._ref[gi][b] = 1
                self._set_table(gi, idx, len(g.blocks), b)
                g.blocks.append(b)
        return True

    def max_coverable(self, idx: int, start: int, want: int) -> int:
        """Largest take <= want such that `ensure(idx, start + take)`
        will succeed right now (window slides applied first): the
        engine's chunk planner asks this instead of reimplementing
        per-group block accounting."""
        seq = self.seqs[idx]
        assert seq is not None, idx
        self.slide_window(idx)
        avail = [self.free_blocks(gi) + len(g.blocks)
                 for gi, g in enumerate(seq.groups)]
        upper = min(start + want, self.capacity)
        take = 0
        # feasibility only changes at block boundaries: walk block counts
        # (<= max_blocks_per_seq iterations), not tokens
        bs = self.block_size
        for nb in range(-(-(start + 1) // bs), -(-upper // bs) + 1):
            if any(nb > a for a in avail):
                break
            take = min(nb * bs, upper) - start
        return take

    def set_length(self, idx: int, n_tokens: int) -> None:
        seq = self.seqs[idx]
        assert seq is not None and n_tokens <= len(seq.blocks) * self.block_size
        seq.length = n_tokens

    def truncate(self, idx: int, n_tokens: int) -> int:
        """Un-write the sequence's tail back to `n_tokens` — the
        speculative-decoding rollback: verification writes K+1 positions
        optimistically, and the rejected suffix must hand its block-table
        coverage back.

        Every block whose logical span lies entirely at/after `n_tokens`
        is dropped from every window group through the normal release
        machinery: shared blocks survive for their other holders
        (decref), registered exclusively-held blocks park in the LRU
        prefix cache (their content is fully committed and still
        attachable), unregistered ones return to the group's free list.
        No cache bytes are touched — reads beyond `seq.length` are
        masked by `kv_len`, and the next write at a kept position simply
        lands over the garbage.

        The committed-hash chain is cut back to the full blocks still
        covered, and a kept tail block that the cut partially
        invalidates is EVICTED from the prefix index: future writes will
        land below its registered content, and a registered block's
        bytes must never change (writers into shared blocks still
        COW-fork as usual). Slide-freed leading holes are never
        resurrected — truncation only ever shortens tables, and the
        slide point is clamped to the new block count. Returns the
        number of group-blocks dropped."""
        seq = self.seqs[idx]
        assert seq is not None and n_tokens >= 0, idx
        bs = self.block_size
        nb = -(-n_tokens // bs)              # blocks still covered
        nfull = n_tokens // bs               # ... of which fully valid
        dropped = 0
        for gi, g in enumerate(seq.groups):
            while len(g.blocks) > nb:
                j = len(g.blocks) - 1
                b = g.blocks.pop()
                if b != TRASH_BLOCK:         # below-slide holes stay holes
                    self._release_block(gi, b)
                    self._set_table(gi, idx, j, TRASH_BLOCK)
                    dropped += 1
            if len(g.hashes) > nfull:
                del g.hashes[nfull:]
                if nfull < nb:
                    # the kept tail block was committed full but is now
                    # partially un-written: evict its index entry before
                    # any future write can diverge from the registered
                    # content (the physical bytes are still intact for
                    # every current sharer — their writes COW-fork)
                    b = g.blocks[nb - 1]
                    h = self._hash_of.pop((gi, b), None)
                    if h is not None:
                        del self._index[(gi, h)]
                        self.prefix_stats["evictions"] += 1
                    lo = self._lo_pending.pop((gi, b), None)
                    if lo is not None:
                        self.host.unpin((gi, lo))
            g.slid = min(g.slid, nb)
        seq.length = min(seq.length, n_tokens)
        return dropped

    def release(self, idx: int) -> None:
        """Decref (not free) every block the sequence holds in any
        group — shared blocks survive for their other holders,
        registered blocks go to the LRU cache."""
        seq = self.seqs[idx]
        if seq is None:
            return
        for gi, g in enumerate(seq.groups):
            for b in reversed(g.blocks):
                if b != TRASH_BLOCK:
                    self._release_block(gi, b)
            # entries beyond len(g.blocks) and below the slide point are
            # already trash by invariant
            for j, b in enumerate(g.blocks):
                if b != TRASH_BLOCK:
                    self._set_table(gi, idx, j, TRASH_BLOCK)
        self.seqs[idx] = None

    def youngest(self) -> int | None:
        """Slot of the most recently admitted live sequence (the
        preemption victim), or None when nothing is live."""
        live = [(s.admitted, i) for i, s in enumerate(self.seqs)
                if s is not None]
        return max(live)[1] if live else None

    # -- prefix caching --------------------------------------------------------
    def _match_plan(self, tokens, allow_host: bool = False
                    ) -> tuple[int, list[tuple[int, list[int | None]]],
                               list[int]]:
        """Group-aware longest servable cached prefix of `tokens`.

        Returns (matched tokens m, per-group (j_lo, block ids for
        logical blocks [j_lo, m/bs)), chain hashes of the matched full
        blocks). A prefill resuming at q0 = min(m, len(tokens)-1) — the
        engine always recomputes >= 1 token — needs, per group, every
        cached block covering positions [q0 - window + 1, m); global
        groups (window None) need the whole from-root run [0, m).
        Slide-freed blocks were evicted from the index, so they can
        never be matched for a local group here.

        With `allow_host`, hashes absent from the device index but
        present in the host tier (or queued for capture — the engine
        always captures before it uploads) are servable too: their plan
        entries are None, and `attach_prefix` allocates fresh device
        blocks + restore jobs for them."""
        bs = self.block_size
        empty = [(0, []) for _ in self.group_windows]
        if not self.prefix_cache:
            return 0, empty, []
        host = self.host if allow_host else None

        def servable(gi: int, h: int) -> bool:
            if (gi, h) in self._index:
                return True
            if host is None:
                return False
            # spill-pending hashes are still device bytes (captured
            # before upload), so only true host entries need the
            # integrity check
            return ((gi, h) in self._spill_pending
                    or self.host_ok(gi, h))

        hashes: list[int] = []
        parent = _ROOT_HASH
        for i in range(min(len(tokens) // bs, self.max_blocks_per_seq)):
            h = _chain_hash(parent, tuple(tokens[i * bs: (i + 1) * bs]))
            hashes.append(h)
            parent = h
        m = len(hashes)
        for gi, w in enumerate(self.group_windows):
            if w:
                continue
            run = 0
            for h in hashes:
                if not servable(gi, h):
                    break
                run += 1
            m = min(m, run)
        while m > 0:
            q0 = min(m * bs, len(tokens) - 1)
            plan: list[tuple[int, list[int | None]]] | None = []
            # when a windowed group is missing block j, every candidate
            # m' in (j, m) still needs j (j_lo shrinks with m), so the
            # next viable candidate is m' = j — one jump per missing
            # block keeps the whole search O(max_blocks_per_seq)
            next_m = m - 1
            for gi, w in enumerate(self.group_windows):
                j_lo = 0 if not w else max(0, q0 - w + 1) // bs
                blks: list[int | None] = []
                for j in range(j_lo, m):
                    b = self._index.get((gi, hashes[j]))
                    if b is None and not (host is not None
                                          and servable(gi, hashes[j])):
                        plan = None
                        next_m = min(next_m, j)
                        break
                    blks.append(b)
                if plan is None:
                    break
                plan.append((j_lo, blks))
            if plan is not None:
                return m * bs, plan, hashes[:m]
            m = next_m
        return 0, empty, []

    def lookup_prefix(self, tokens, allow_host: bool = False) -> int:
        """Matched-prefix length in tokens (no side effects) — the
        largest offset a prefill could resume at with every window
        group's needed blocks cached (on device, or — with `allow_host`
        — restorable from the host tier)."""
        return self._match_plan(tokens, allow_host)[0]

    def prefix_admit_discount(self, tokens) -> tuple[int, ...]:
        """Per-group blocks the admission watermark may discount for
        `tokens`: matched blocks held LIVE by other sequences (sharing
        them costs nothing). Matched blocks parked in a group's LRU pool
        are already counted by `free_blocks()`, so discounting them too
        would double-count. Feed the result straight to
        `try_allocate(cached_blocks=...)`."""
        if not self.prefix_cache:
            return (0,) * self.n_groups
        _, plan, _ = self._match_plan(tokens)
        return tuple(sum(1 for b in blks
                         if b is not None and self._ref[gi][b] > 0)
                     for gi, (_, blks) in enumerate(plan))

    def attach_prefix(self, idx: int, tokens, allow_host: bool = False
                      ) -> int:
        """Share the longest cached servable prefix of `tokens` into
        freshly-allocated slot `idx` (incref each matched block, pull
        zero-ref ones out of the LRU pool). Windowed groups attach only
        the blocks covering the resume position's lookback window and
        start pre-slid below it. Returns the matched token count; the
        caller starts prefill at that offset (recomputing at least one
        token — `cow_for_write` forks the tail block if that recompute
        lands in a shared one).

        With `allow_host`, prefix blocks living only in the host tier
        are re-admitted: a fresh device block is allocated and
        registered for each, a restore job is queued for the engine's
        scatter-upload drain, and the block is marked unrestored (rows
        holding one are gated out of chunk scheduling until the bytes
        arrive). If the free pool cannot cover the host hits, the match
        falls back to device-resident blocks only."""
        seq = self.seqs[idx]
        assert seq is not None and not any(g.blocks for g in seq.groups), \
            "attach before ensure"
        if not self.prefix_cache:
            return 0
        m_tokens, plan, hashes = self._match_plan(tokens, allow_host)
        if allow_host:
            # all-or-nothing feasibility for the host hits: the fresh
            # blocks they need must come from the free list + LRU pool
            # MINUS the plan's own device-matched LRU residents (about
            # to be pulled out and increfed, so not allocatable)
            for gi, (_, blks) in enumerate(plan):
                need = sum(1 for b in blks if b is None)
                lru_held = sum(1 for b in blks
                               if b is not None and self._ref[gi][b] == 0)
                if need > self.free_blocks(gi) - lru_held:
                    m_tokens, plan, hashes = self._match_plan(tokens, False)
                    break
        shared = restored = 0
        # pass 1: incref every device-matched block FIRST, so the host
        # hits' allocations below can never reclaim a plan block out of
        # the LRU pool
        for gi, (g, (j_lo, blks)) in enumerate(zip(seq.groups, plan)):
            g.blocks = [TRASH_BLOCK] * j_lo + list(blks)
            g.hashes = list(hashes)
            g.slid = j_lo
            for j, b in enumerate(blks, start=j_lo):
                if b is None:
                    continue
                if self._ref[gi][b] == 0:
                    del self._lru[gi][b]
                self._ref[gi][b] += 1
                self._set_table(gi, idx, j, b)
            shared += len(blks)
        # pass 2: allocate + queue a restore for each host hit
        for gi, (g, (j_lo, blks)) in enumerate(zip(seq.groups, plan)):
            for j, src in enumerate(blks, start=j_lo):
                if src is not None:
                    continue
                b = self._alloc_block(gi)
                assert b is not None, "host-hit feasibility pre-checked"
                h = hashes[j]
                self._ref[gi][b] = 1
                self._index[(gi, h)] = b
                self._hash_of[(gi, b)] = h
                g.blocks[j] = b
                self._set_table(gi, idx, j, b)
                self._ticket += 1
                self._unrestored[(gi, b)] = (self._ticket, h)
                self.restore_jobs.append((gi, b, h, self._ticket))
                if (gi, h) in self.host:
                    self.host.pin((gi, h))   # spill-pending entries are
                else:                        # pinned at capture time
                    assert (gi, h) in self._spill_pending, (gi, h)
                restored += 1
        seq.length = m_tokens
        st = self.prefix_stats
        st["queries"] += 1
        st["lookup_tokens"] += len(tokens)
        st["hit_tokens"] += m_tokens
        st["blocks_shared"] += shared
        st["host_hit_blocks"] += restored
        return m_tokens

    def cow_for_write(self, idx: int, start: int, end: int
                      ) -> list[tuple[int, int, int]] | None:
        """Copy-on-write fork of every shared block that the token write
        range [start, end) touches, in every window group: allocate a
        private replacement in that group's id space, decref the shared
        original, and return (group, src, dst) triples whose cache
        bytes — the GROUP'S layer rows only — the CALLER must copy
        before writing. Returns None when a fork cannot be allocated
        (some group's pool truly exhausted — caller preempts). Blocks
        must already be ensured over the range; slide-freed holes need
        no fork (their writes land in the trash block)."""
        seq = self.seqs[idx]
        assert seq is not None and end <= len(seq.blocks) * self.block_size
        span = range(start // self.block_size, -(-end // self.block_size))
        # all-or-nothing: check every fork is allocatable BEFORE mutating,
        # so a failure never strands completed forks whose (src, dst)
        # pairs the caller would lose (bytes never copied -> stale reads)
        for gi, g in enumerate(seq.groups):
            if sum(1 for bi in span
                   if g.blocks[bi] != TRASH_BLOCK
                   and self._ref[gi][g.blocks[bi]] > 1) \
                    > self.free_blocks(gi):
                return None
        triples: list[tuple[int, int, int]] = []
        for gi, g in enumerate(seq.groups):
            for bi in span:
                src = g.blocks[bi]
                if src == TRASH_BLOCK or self._ref[gi][src] <= 1:
                    continue
                dst = self._alloc_block(gi)
                assert dst is not None        # guarded above
                self._ref[gi][dst] = 1
                self._release_block(gi, src)
                g.blocks[bi] = dst
                self._set_table(gi, idx, bi, dst)
                triples.append((gi, src, dst))
                self.prefix_stats["cow_forks"] += 1
        return triples

    def commit(self, idx: int, n_tokens: int, tokens) -> None:
        """Record that positions [0, n_tokens) now hold the KV of
        `tokens[:n_tokens]`, and register every newly-FULL block in the
        per-group content-hash index so later sequences can share it
        (slide-freed holes extend the hash chain but register nothing).
        `tokens` must be the sequence's full committed token stream."""
        self.set_length(idx, n_tokens)
        if not self.prefix_cache:
            return
        seq = self.seqs[idx]
        bs = self.block_size
        for gi, g in enumerate(seq.groups):
            parent = g.hashes[-1] if g.hashes else _ROOT_HASH
            for bi in range(len(g.hashes), n_tokens // bs):
                h = _chain_hash(parent, tuple(tokens[bi * bs: (bi + 1) * bs]))
                b = g.blocks[bi]
                if b != TRASH_BLOCK and (gi, h) not in self._index \
                        and (gi, b) not in self._hash_of:
                    self._index[(gi, h)] = b
                    self._hash_of[(gi, b)] = h
                g.hashes.append(h)
                parent = h

    # -- tiered KV: host offload + restore ------------------------------------
    def _forget_restore(self, g: int, b: int) -> None:
        """Void block (g, b)'s pending restore: drop the ticket (the
        queued job dies at claim time) and release its host-entry pin.
        A job against a still-spill-pending entry never took a pin (pins
        are applied at capture, `store_spill`), so there is nothing to
        release in that case."""
        _, h = self._unrestored.pop((g, b))
        if (g, h) in self.host and self.host.pinned((g, h)):
            self.host.unpin((g, h))

    def host_ok(self, g: int, h: int) -> bool:
        """Is host entry (g, h) present AND integrity-clean? A checksum
        mismatch drops the entry (when unpinned; pinned copies are left
        for the restore drain to handle) so later matches recompute
        instead of restoring garbage."""
        key = (g, h)
        if self.host is None or key not in self.host:
            return False
        if self.host.verify(key):
            return True
        if not self.host.pinned(key):
            self.host.discard(key)
        return False

    def rows_holding(self, g: int, b: int) -> list[int]:
        """Slot indices whose block table references physical block
        (g, b) — the owners a corrupt-restore fallback must preempt."""
        return [idx for idx, s in enumerate(self.seqs)
                if s is not None and b in s.groups[g].blocks]

    def purge_block(self, g: int, b: int) -> None:
        """Evict a zero-ref block outright — deregister its content and
        return it to the free list (corrupt-fallback path: the block's
        bytes must never be prefix-matched again)."""
        assert self._ref[g][b] == 0, f"purge of live block {g}/{b}"
        self._lru[g].pop(b, None)
        h = self._hash_of.pop((g, b), None)
        if h is not None:
            del self._index[(g, h)]
        if b not in self._free[g]:
            self._free[g].append(b)

    def take_spills(self) -> list[tuple[int, int, int]]:
        """Drain the (group, block, hash) capture queue. The caller
        (engine `_flush_spills`) must gather + device_get these blocks'
        pool bytes and hand them to `store_spill` BEFORE any
        cache-writing dispatch — the evicted ids are already back in
        circulation and their bytes survive only until the next write
        lands."""
        out, self._spill_queue = self._spill_queue, []
        self._spill_pending.clear()
        return out

    def store_spill(self, g: int, h: int, planes: dict) -> None:
        """Deposit one captured block in the host tier and apply the
        pins any already-queued restore jobs deferred (a job created
        while its entry was still spill-pending could not pin it)."""
        self.host.put((g, h), planes)
        pins = sum(1 for (gi, _b), (_t, hh) in self._unrestored.items()
                   if gi == g and hh == h)
        for _ in range(pins):
            self.host.pin((g, h))

    def claim_restore(self, g: int, b: int, h: int, ticket: int) -> bool:
        """True iff a drained restore job is still wanted: the dst block
        is still attached and the ticket is current (a release/preempt
        of the holder voids the job — the block id may since have been
        reallocated for something else entirely)."""
        return self._unrestored.get((g, b)) == (ticket, h)

    def finish_restore(self, g: int, b: int, h: int,
                       lo_pending: bool = False) -> None:
        """Mark block (g, b) device-resident again. `lo_pending`
        (planar pools) records that only the fp8 hi planes were
        uploaded: the host entry stays pinned as the lazy lo-plane
        source until the first FP16-mode touch."""
        del self._unrestored[(g, b)]
        if lo_pending:
            self._lo_pending[(g, b)] = h     # inherits the job's pin
        else:
            self.host.unpin((g, h))

    def row_unrestored(self, idx: int) -> bool:
        """Does slot `idx` hold any block whose restore has not landed?
        The engine gates such rows out of chunk scheduling — a prefill
        reading them would see garbage."""
        seq = self.seqs[idx]
        if seq is None or not self._unrestored:
            return False
        return any((gi, b) in self._unrestored
                   for gi, g in enumerate(seq.groups) for b in g.blocks)

    def take_lo_pending(self) -> list[tuple[int, int, int]]:
        """Drain ALL lazily-deferred lo-plane uploads as (group, block,
        hash) — the engine's first FP16-mode dispatch must be preceded
        by these bytes. Host-entry pins transfer to the caller, which
        unpins after the upload."""
        out = [(g, b, h) for (g, b), h in self._lo_pending.items()]
        self._lo_pending.clear()
        return out

    def take_lo_pending_for(self, pairs) -> list[tuple[int, int, int]]:
        """Drain the lo-plane uploads for specific (group, block) pairs
        — the write-range guard: a write into a lo-pending block must
        not race a later whole-block lo scatter (the scatter would
        clobber the fresh lo bytes with the stale host copy)."""
        out = []
        for g, b in pairs:
            h = self._lo_pending.pop((g, b), None)
            if h is not None:
                out.append((g, b, h))
        return out

    def lo_pending_in_range(self, idx: int, start: int, end: int
                            ) -> list[tuple[int, int]]:
        """(group, block) pairs with deferred lo planes that the token
        write range [start, end) on slot `idx` touches."""
        if not self._lo_pending:
            return []
        seq = self.seqs[idx]
        span = range(start // self.block_size, -(-end // self.block_size))
        return [(gi, g.blocks[bi]) for gi, g in enumerate(seq.groups)
                for bi in span if bi < len(g.blocks)
                and (gi, g.blocks[bi]) in self._lo_pending]

    def mirror_jobs(self) -> list[tuple[int, int, int]]:
        """(group, block, hash) of every registered device block NOT yet
        mirrored in the host tier — `save_prefix_store` captures these
        (without evicting anything) so the serialized store covers the
        whole prefix index. Unrestored blocks hold garbage and are
        skipped (their content is already hosted by definition)."""
        if self.host is None:
            return []
        return [(g, b, h) for (g, h), b in self._index.items()
                if (g, h) not in self.host
                and (g, h) not in self._spill_pending
                and (g, b) not in self._unrestored]

    # -- invariant audit (tests) ----------------------------------------------
    def check_invariants(self) -> None:
        ref = [[0] * (self.n_blocks + 1) for _ in range(self.n_groups)]
        for s in self.seqs:
            if s is None:
                continue
            for gi, g in enumerate(s.groups):
                for b in g.blocks:
                    if b != TRASH_BLOCK:
                        ref[gi][b] += 1
        assert ref == self._ref, (ref, self._ref)
        for gi in range(self.n_groups):
            free, lru = set(self._free[gi]), set(self._lru[gi])
            assert not (free & lru), f"group {gi} block both free and cached"
            for b in range(1, self.n_blocks + 1):
                if self._ref[gi][b] == 0:
                    assert (b in free) ^ (b in lru), \
                        f"zero-ref block {gi}/{b} neither free nor " \
                        f"cached (or both)"
                else:
                    assert b not in free and b not in lru, \
                        f"live block {gi}/{b} on the free/cached list"
        assert set(self._hash_of) == {(g, b) for (g, _h), b
                                      in self._index.items()}
        for (gi, h), b in self._index.items():
            assert self._hash_of[(gi, b)] == h
            assert b not in self._free[gi], \
                f"indexed block {gi}/{b} on the free list"
        for i, s in enumerate(self.seqs):
            for gi in range(self.n_groups):
                row = np.full(self.max_blocks_per_seq, TRASH_BLOCK, np.int32)
                if s is not None:
                    gl = s.groups[gi].blocks
                    row[: len(gl)] = gl
                assert (self._tables[gi, i] == row).all(), \
                    f"stale table row (group {gi}, slot {i})"
            if s is None:
                continue
            for g, w in zip(s.groups, self.group_windows):
                if not w:
                    assert g.slid == 0, "global group slid"
                assert all(b == TRASH_BLOCK for b in g.blocks[: g.slid]), \
                    "live block below the slide point"
                assert all(b != TRASH_BLOCK for b in g.blocks[g.slid:]), \
                    "hole above the slide point"
        # tiered-KV: unrestored blocks are live and registered-or-voided,
        # spill-pending entries are not yet hosted, lo-pending blocks are
        # live or LRU-parked with a hosted (and pinned) source, and the
        # host tier's pin/byte accounting is exact
        for (g, b), (_t, h) in self._unrestored.items():
            assert self._ref[g][b] > 0, f"unrestored block {g}/{b} unheld"
            assert b not in self._free[g] and b not in self._lru[g]
        qhashes = {(g, h) for g, _b, h in self._spill_queue}
        assert qhashes == self._spill_pending, \
            (qhashes, self._spill_pending)
        if self.host is not None:
            for g, h in self._spill_pending:
                assert (g, h) not in self.host, \
                    f"spill queued for already-hosted entry {g}/{h}"
            for (g, b), h in self._lo_pending.items():
                assert (g, h) in self.host, f"lo-pending {g}/{b} unsourced"
                assert self.host.pinned((g, h)), f"lo source {g}/{h} unpinned"
                assert self._ref[g][b] > 0 or b in self._lru[g], \
                    f"lo-pending block {g}/{b} neither live nor cached"
            want_pins: collections.Counter = collections.Counter()
            for (g, _b), h in self._lo_pending.items():
                want_pins[(g, h)] += 1
            for (g, _b), (_t, h) in self._unrestored.items():
                if (g, h) in self.host:
                    want_pins[(g, h)] += 1
            assert want_pins == self.host._pins, \
                (dict(want_pins), dict(self.host._pins))
            assert self.host.bytes == sum(
                self.host.entry_bytes(p) for p in self.host.entries.values())
            assert set(self.host._sums) == set(self.host.entries), \
                "host checksum map out of sync with entries"
        if self._dev_tables is not None:
            # read-only check: overlay the pending dirty entries on the
            # mirror instead of flushing (device_tables() would mutate
            # the very h2d counters the bench rows report)
            # nfp: ignore[NFP001] opt-in debug sanitizer: auditing the device mirror IS the sync
            mirror = np.asarray(self._dev_tables).copy()
            for (g, s, j), b in self._dirty.items():
                mirror[g, s, j] = b
            assert (mirror == self._tables).all(), \
                "device table mirror diverged from the host tables"
