"""Slot-based KV cache manager for the continuous-batching engine.

A fixed pool of `n_slots` sequence slots, each with `capacity` token
positions, backed by the model's stacked cache pytree (batch dim = slot).
Paged-attention-style block indirection is overkill for the engine's
fixed-capacity slots; the manager instead tracks per-slot lengths and
recycles slots on completion — the properties the paper's serving story
needs (KV memory bounds the admissible batch; NestedFP's zero-overhead
weights leave more HBM for these slots, paper §3.3).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Slot:
    request_id: str | None = None
    length: int = 0
    max_new: int = 0
    generated: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, n_slots: int, capacity: int):
        self.n_slots = n_slots
        self.capacity = capacity
        self.slots = [Slot() for _ in range(n_slots)]

    def try_allocate(self, request_id: str, prompt_len: int,
                     max_new: int) -> int | None:
        if prompt_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {prompt_len}+{max_new} exceeds "
                f"slot capacity {self.capacity}")
        for i, s in enumerate(self.slots):
            if s.free:
                self.slots[i] = Slot(request_id, prompt_len, max_new, 0)
                return i
        return None

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def n_free(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def utilization(self) -> float:
        used = sum(s.length for s in self.slots if not s.free)
        return used / (self.n_slots * self.capacity)
