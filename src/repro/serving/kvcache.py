"""KV-cache managers for the continuous-batching engine.

Two layouts:

* `SlotManager` — legacy fixed-slot layout: a pool of `n_slots` sequence
  slots, each pre-reserving `capacity` token positions in the model's
  stacked cache pytree (batch dim = slot). Still used for cache families
  without paged support (SSM state, MLA latents, enc-dec memories).

* `BlockManager` — block-paged layout (the paper's §3.3 serving story:
  KV memory bounds the admissible batch, so reserving `capacity` tokens
  per slot wastes exactly the HBM that NestedFP's zero-overhead weights
  reclaim). Physical KV lives in a pool of fixed-size token blocks;
  each sequence owns an ordered block table and grows one block at a
  time. Admission is driven by free blocks, not free slots, and when
  blocks run out the youngest sequence is preempted (blocks released,
  request recomputed later — vLLM-style recompute preemption).

Physical block 0 is reserved as a trash block: jit'd steps always write
a full (possibly padded) chunk, and pad/inactive-row writes are pointed
at block 0 so they can never clobber live cache state.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Slot:
    request_id: str | None = None
    length: int = 0
    max_new: int = 0
    generated: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, n_slots: int, capacity: int):
        self.n_slots = n_slots
        self.capacity = capacity
        self.slots = [Slot() for _ in range(n_slots)]

    def try_allocate(self, request_id: str, prompt_len: int,
                     max_new: int) -> int | None:
        if prompt_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {prompt_len}+{max_new} exceeds "
                f"slot capacity {self.capacity}")
        for i, s in enumerate(self.slots):
            if s.free:
                self.slots[i] = Slot(request_id, prompt_len, max_new, 0)
                return i
        return None

    def release(self, idx: int) -> None:
        self.slots[idx] = Slot()

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def n_free(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def utilization(self) -> float:
        used = sum(s.length for s in self.slots if not s.free)
        return used / (self.n_slots * self.capacity)


TRASH_BLOCK = 0


@dataclasses.dataclass
class _Seq:
    request_id: str
    blocks: list[int]          # physical block ids, logical order
    length: int = 0            # tokens committed to the cache
    admitted: int = 0          # admission counter (largest == youngest)


class BlockManager:
    """Free-list allocator of fixed-size KV blocks with per-sequence
    block tables.

    `n_blocks` counts USABLE blocks; physical block 0 (trash) is extra,
    so pools must be allocated with `n_total_blocks` blocks. Unassigned
    block-table entries point at the trash block — reads through them
    are masked by per-row lengths, writes land in garbage space.
    """

    def __init__(self, n_slots: int, block_size: int, n_blocks: int,
                 max_blocks_per_seq: int):
        assert block_size > 0 and n_blocks > 0
        self.n_slots = n_slots
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.max_blocks_per_seq = max_blocks_per_seq
        # pop() hands out low block ids first (deterministic layouts in tests)
        self._free = list(range(n_blocks, 0, -1))
        self.seqs: list[_Seq | None] = [None] * n_slots
        self._admissions = 0

    # -- pool-level views ------------------------------------------------------
    @property
    def n_total_blocks(self) -> int:
        return self.n_blocks + 1                     # + trash block 0

    @property
    def capacity(self) -> int:
        """Max tokens a single sequence can hold."""
        return self.max_blocks_per_seq * self.block_size

    def n_free_blocks(self) -> int:
        return len(self._free)

    def n_free_slots(self) -> int:
        return sum(1 for s in self.seqs if s is None)

    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def utilization(self) -> float:
        return self.blocks_in_use() / self.n_blocks

    def table(self, idx: int):
        """(max_blocks_per_seq,) int32 block table for one slot; holes
        point at the trash block."""
        import numpy as np
        row = np.full(self.max_blocks_per_seq, TRASH_BLOCK, np.int32)
        seq = self.seqs[idx]
        if seq is not None:
            row[: len(seq.blocks)] = seq.blocks
        return row

    def tables(self):
        import numpy as np
        return np.stack([self.table(i) for i in range(self.n_slots)])

    # -- sequence lifecycle ----------------------------------------------------
    def try_allocate(self, request_id: str, seq_len: int,
                     max_new: int) -> int | None:
        """Claim a slot for a sequence (no blocks yet — `ensure` grows
        them chunk by chunk). None when no slot is free or when the
        first chunk could not possibly be admitted (fewer free blocks
        than the whole prompt needs — the admission watermark that keeps
        preemption for decode-time growth, not thrashing admissions)."""
        if seq_len + max_new > self.capacity:
            raise ValueError(
                f"request {request_id}: {seq_len}+{max_new} exceeds paged "
                f"capacity {self.capacity}")
        if -(-(seq_len + max_new) // self.block_size) > self.n_blocks:
            raise ValueError(
                f"request {request_id}: needs more blocks than the whole "
                f"pool holds ({self.n_blocks}) — would preempt-thrash forever")
        need = -(-max(seq_len, 1) // self.block_size)
        if need > len(self._free):
            return None
        for i, s in enumerate(self.seqs):
            if s is None:
                self._admissions += 1
                self.seqs[i] = _Seq(request_id, [], 0, self._admissions)
                return i
        return None

    def ensure(self, idx: int, n_tokens: int) -> bool:
        """Grow slot `idx`'s block table to cover positions [0, n_tokens).
        All-or-nothing; False when the free list runs dry (caller
        preempts or defers)."""
        seq = self.seqs[idx]
        assert seq is not None, idx
        need = -(-n_tokens // self.block_size) - len(seq.blocks)
        if need <= 0:
            return True
        if n_tokens > self.capacity or need > len(self._free):
            return False
        for _ in range(need):
            seq.blocks.append(self._free.pop())
        return True

    def set_length(self, idx: int, n_tokens: int) -> None:
        seq = self.seqs[idx]
        assert seq is not None and n_tokens <= len(seq.blocks) * self.block_size
        seq.length = n_tokens

    def release(self, idx: int) -> None:
        seq = self.seqs[idx]
        if seq is None:
            return
        self._free.extend(reversed(seq.blocks))
        self.seqs[idx] = None

    def youngest(self) -> int | None:
        """Slot of the most recently admitted live sequence (the
        preemption victim), or None when nothing is live."""
        live = [(s.admitted, i) for i, s in enumerate(self.seqs)
                if s is not None]
        return max(live)[1] if live else None
