"""Exact FLOP counting from the lowered jaxpr.

XLA's compiled cost_analysis counts while-loop bodies ONCE regardless of
trip count (verified in EXPERIMENTS.md §Dry-run), which undercounts any
lax.scan'd model by ~n_layers x n_microbatches. This module walks the
jaxpr of the SAME step function instead: dot_generals are counted exactly
(2·batch·M·N·K) and scans multiply their body by the trip count — giving
the true global FLOPs the 512-device program executes.

Elementwise ops are charged one FLOP per output element (VPU work, a few
percent of total); ops with no arithmetic (reshape/transpose/slice/...)
are free.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.extend import core as jcore

_FREE = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "pad",
    "convert_element_type", "bitcast_convert_type", "gather", "scatter",
    "scatter-add", "rev", "iota", "copy", "stop_gradient", "device_put",
    "split", "select_n", "reduce_precision",
}

_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _size(aval) -> float:
    try:
        return float(math.prod(aval.shape)) if aval.shape else 1.0
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (eqn.invars[0].aval, eqn.invars[1].aval)
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    batch = math.prod([lhs.shape[i] for i in lb]) if lb else 1
    contract = math.prod([lhs.shape[i] for i in lc]) if lc else 1
    m = math.prod([d for i, d in enumerate(lhs.shape)
                   if i not in lc and i not in lb])
    n = math.prod([d for i, d in enumerate(rhs.shape)
                   if i not in rc and i not in rb])
    return 2.0 * batch * m * n * contract


def _maybe_sub(params: dict) -> list[Any]:
    subs = []
    for k in _SUBJAXPR_PARAMS:
        if k in params and params[k] is not None:
            subs.append(params[k])
    if "branches" in params:
        subs.extend(params["branches"])
    return subs


def count_jaxpr(jaxpr) -> float:
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "scan":
            body = count_jaxpr(eqn.params["jaxpr"])
            total += body * eqn.params["length"]
        elif prim == "while":
            # we never emit unbounded whiles from model code; charge once
            total += count_jaxpr(eqn.params["body_jaxpr"])
        elif prim == "cond":
            total += max((count_jaxpr(b) for b in eqn.params["branches"]),
                         default=0.0)
        elif _maybe_sub(eqn.params):
            for sub in _maybe_sub(eqn.params):
                total += count_jaxpr(sub)
        elif prim in _FREE:
            continue
        else:
            # elementwise / reduction proxy: one flop per output element
            total += sum(_size(v.aval) for v in eqn.outvars)
    return total


def count_step_flops(fn, *example_args) -> float:
    """Global FLOPs of fn(*example_args) (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*example_args)
    return count_jaxpr(closed)


def scan_trip_info(fn, *example_args) -> dict[str, Any]:
    """Scan lengths by nesting depth (for collective trip correction).

    Returns {"by_depth": [d1, d2, ...]} where d_i is the max scan length
    at depth i (depth 1 = outermost). Multiple same-depth scans (e.g.
    enc + dec stacks) take the max — a conservative, documented choice."""
    closed = jax.make_jaxpr(fn)(*example_args)
    by_depth: dict[int, int] = {}

    def walk(jaxpr, depth):
        if isinstance(jaxpr, jcore.ClosedJaxpr):
            jaxpr = jaxpr.jaxpr
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "scan":
                d = depth + 1
                ln = int(eqn.params["length"])
                by_depth[d] = max(by_depth.get(d, 1), ln)
                walk(eqn.params["jaxpr"], d)
            else:
                for sub in _maybe_sub(eqn.params):
                    walk(sub, depth)

    walk(closed, 0)
    flat = [by_depth[d] for d in sorted(by_depth)]
    return {"by_depth": flat, "scan_lengths": flat}
