"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §6).

Terms per (arch × shape × mesh), all PER-CHIP (cost_analysis reports the
post-SPMD per-device program; verified against a hand-checked example):

    compute    = flops / PEAK_FLOPS
    memory     = bytes_accessed / HBM_BW
    collective = Σ_type bytes_type · mult_type / ICI_BW

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. FP8-mode GEMMs run the MXU at 2× bf16; XLA:CPU cost
analysis cannot know that, so fp8 rows also report `compute_fp8_adj`
(= compute / 2 on the GEMM-dominated fraction — conservative: we apply it
to the whole FLOP count and flag it as a bound).
"""

from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

# bytes-on-wire multiplier per collective (ring algorithms, large n)
_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_FN_OPEN_RE = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")
_WHILE_BODY_RE = re.compile(r"\bwhile\(.*?body=(%?[\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALL_RE = re.compile(
    r"\b(?:condition|to_apply|calls)=\{?(%?[\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


def _parse_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _FN_OPEN_RE.match(line.strip())
        if m:
            cur = "__ENTRY__" if m.group(1) else m.group(2).lstrip("%")
            comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _loop_multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Execution multiplier of every computation: product of the EXACT
    known_trip_count annotations along its while ancestry (XLA emits
    these on CPU/TPU when the induction variable is static). Computations
    reached via non-while calls (fusions, reducers, conds) inherit the
    caller's multiplier."""
    mult = {"__ENTRY__": 1.0}
    frontier = ["__ENTRY__"]
    seen_edges = set()
    while frontier:
        nxt = []
        for name in frontier:
            m0 = mult[name]
            for line in comps.get(name, ()):
                called: list[tuple[str, float]] = []
                wb = _WHILE_BODY_RE.search(line)
                if wb:
                    tm = _TRIP_RE.search(line)
                    trips = float(tm.group(1)) if tm else 1.0
                    called.append((wb.group(1).lstrip("%"), m0 * trips))
                for cm in _CALL_RE.finditer(line):
                    for c in cm.group(1).split(","):
                        c = c.strip().lstrip("%").rstrip("}")
                        if c:
                            called.append((c, m0))
                for cname, cm_ in called:
                    if cname in comps and mult.get(cname, 0.0) < cm_ \
                            and (name, cname, cm_) not in seen_edges:
                        seen_edges.add((name, cname, cm_))
                        mult[cname] = cm_
                        nxt.append(cname)
        frontier = nxt
    return mult


def collective_bytes(hlo_text: str, *, trips_by_depth: list[float] | None = None
                     ) -> dict[str, Any]:
    """Collective result-shape bytes from the (per-device) optimized HLO.

    XLA emits while-loop bodies once in the text; each collective's bytes
    are multiplied by its computation's execution count, read from the
    exact `known_trip_count` while annotations (product over the loop
    ancestry). `-done` ops skipped (async pairs). `trips_by_depth` is a
    jaxpr-derived fallback for text without trip annotations."""
    comps = _parse_computations(hlo_text)
    mults = _loop_multipliers(comps)
    fallback = 1.0
    for t in (trips_by_depth or []):
        fallback *= t
    per_type: dict[str, float] = {}
    counts: dict[str, int] = {}
    once_total = 0.0
    unattributed = 0

    for name, lines in comps.items():
        m0 = mults.get(name)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m or m.group("suffix") == "-done":
                continue
            op = m.group("op")
            b = _shape_bytes(m.group("shapes"))
            mm = m0 if m0 is not None else fallback
            if m0 is None:
                unattributed += 1
            per_type[op] = per_type.get(op, 0.0) + b * mm
            counts[op] = counts.get(op, 0) + 1
            once_total += b
    weighted = sum(_MULT[t] * b for t, b in per_type.items())
    return {"bytes_by_type": per_type, "counts_by_type": counts,
            "bytes_once_total": once_total,
            "n_unattributed": unattributed,
            "weighted_wire_bytes": weighted}


def roofline_terms(flops: float, bytes_accessed: float,
                   weighted_coll_bytes: float, *, fp8: bool = False
                   ) -> dict[str, float]:
    compute = flops / PEAK_FLOPS
    terms = {
        "compute_s": compute,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": weighted_coll_bytes / ICI_BW,
    }
    if fp8:
        terms["compute_fp8_adj_s"] = compute / 2.0
    key = max(("compute_s", "memory_s", "collective_s"), key=terms.__getitem__)
    terms["dominant"] = key
    terms["bound_step_s"] = terms[key]
    return terms


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-compute reference)
# ---------------------------------------------------------------------------

def count_params(param_tree, *, active_expert_fraction: float | None = None
                 ) -> dict[str, float]:
    """Total + active params from a ShapeDtypeStruct tree. Expert banks
    (leading dim = n_experts paths w_gate/w_up/w_down) are scaled by
    `active_expert_fraction` for the active count."""
    import jax

    total = 0.0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_tree)[0]:
        keys = [str(getattr(k, "key", getattr(k, "name", "")))
                for k in path]
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        if active_expert_fraction is not None and any(
                k in ("w_gate", "w_up", "w_down") for k in keys):
            active += n * active_expert_fraction
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(cfg, shape, n_params_active: float) -> float:
    """6·N·D for training, 2·N·tokens for serving steps (per whole step,
    all chips)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_params_active * tokens
