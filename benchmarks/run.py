"""Benchmark harness entry point (deliverable d): one module per paper
table/figure. Prints one ``name,json`` record per row.

  python -m benchmarks.run [--only applicability,accuracy,...] [--full]
"""

from __future__ import annotations

import argparse
import json
import time


SUITES = ["applicability", "accuracy", "kernel_overhead", "e2e_throughput",
          "slo_trace", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--full", action="store_true",
                    help="full shape sweeps (slower)")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES

    for suite in only:
        t0 = time.time()
        if suite == "applicability":
            from benchmarks import bench_applicability as b
            rows = b.run()
        elif suite == "accuracy":
            from benchmarks import bench_accuracy as b
            rows = b.run()
        elif suite == "kernel_overhead":
            from benchmarks import bench_kernel_overhead as b
            rows = b.run(quick=not args.full)
        elif suite == "e2e_throughput":
            from benchmarks import bench_e2e_throughput as b
            rows = b.run()
        elif suite == "slo_trace":
            from benchmarks import bench_slo_trace as b
            rows = b.run()
        elif suite == "roofline":
            from benchmarks import bench_roofline as b
            rows = b.run()
        else:
            raise SystemExit(f"unknown suite {suite}")
        for r in rows:
            name = r.pop("name")
            print(f"{name},{json.dumps(r, sort_keys=True)}")
        print(f"# {suite}: {len(rows)} rows in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
