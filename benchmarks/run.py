"""Benchmark harness entry point (deliverable d): one module per paper
table/figure. Prints one ``name,json`` record per row and consolidates
every row into ``BENCH_results.json`` (name -> row dict) so CI can
upload the file as an artifact and the perf trajectory is tracked
across PRs.

  python -m benchmarks.run [--only applicability,accuracy,...] [--full]
                           [--json-out BENCH_results.json]
"""

from __future__ import annotations

import argparse
import json
import time


SUITES = ["applicability", "accuracy", "kernel_overhead", "e2e_throughput",
          "slo_trace", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--full", action="store_true",
                    help="full shape sweeps (slower)")
    ap.add_argument("--json-out", default="BENCH_results.json",
                    help="consolidated per-row results file "
                         "(name -> row dict); '' disables")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES

    results: dict[str, dict] = {}
    for suite in only:
        t0 = time.time()
        if suite == "applicability":
            from benchmarks import bench_applicability as b
            rows = b.run()
        elif suite == "accuracy":
            from benchmarks import bench_accuracy as b
            rows = b.run()
        elif suite == "kernel_overhead":
            from benchmarks import bench_kernel_overhead as b
            rows = b.run(quick=not args.full)
        elif suite == "e2e_throughput":
            from benchmarks import bench_e2e_throughput as b
            rows = b.run()
        elif suite == "slo_trace":
            from benchmarks import bench_slo_trace as b
            rows = b.run()
        elif suite == "roofline":
            from benchmarks import bench_roofline as b
            rows = b.run()
        else:
            raise SystemExit(f"unknown suite {suite}")
        for r in rows:
            name = r.pop("name")
            results[name] = r
            print(f"{name},{json.dumps(r, sort_keys=True)}")
        print(f"# {suite}: {len(rows)} rows in {time.time()-t0:.1f}s")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"# wrote {len(results)} rows to {args.json_out}")


if __name__ == "__main__":
    main()
