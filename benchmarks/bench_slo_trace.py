"""Paper Fig. 1b: SLO compliance under a bursty trace — FP16 vs FP8 vs
dual-precision (NestedFP) on the Azure-like arrival process — plus a
functional paged-engine run under the same burst shape reporting KV-block
utilization and preemption counts (the memory-pressure signals the
modeled rows abstract away)."""

from __future__ import annotations

from repro.serving import simulate, trace


def run() -> list[dict]:
    reqs = trace.azure_like(duration_s=60, mean_rate=5.05, seed=7,
                            prompt_len=256, max_new=512)
    cost = simulate.CostModel(fixed_ms=2.0, weight_read_ms_fp16=16.0,
                              weight_read_ms_fp8=8.0, kv_ms_per_ktoken=0.002,
                              compute_ms_per_token_fp16=0.055,
                              compute_ms_per_token_fp8=0.0275)
    rows = []
    for pol in ("fp16", "fp8", "dual"):
        r = simulate.simulate(reqs, cost, policy=pol)
        d = r.row()
        d["name"] = f"slo_trace/{pol}"
        rows.append(d)
    rows.append(measured_paged_engine())
    return rows


def measured_paged_engine(n_requests: int = 12) -> dict:
    """Burst n_requests into a deliberately scarce paged pool: admission
    is block-driven, decode growth preempts the youngest sequences, and
    every request still completes (recompute preemption)."""
    import jax
    import numpy as np

    from repro.configs import ARCHS
    from repro.core.policy import DualPrecisionController, SLOConfig
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sparams = to_serving(params)
    ctrl = DualPrecisionController(SLOConfig(tpot_ms=33.3),
                                  fp16_ms_per_token=0.2,
                                  fp8_ms_per_token=0.1)
    rng = np.random.RandomState(1)
    eng = Engine(cfg, sparams, n_slots=6, capacity=64, controller=ctrl,
                 block_size=8, n_blocks=24, chunk_tokens=64)
    for i in range(n_requests):
        eng.submit(Request(f"r{i}", list(rng.randint(1, 400, 24)),
                           max_new=12))
    fin = eng.run()
    return {"name": "slo_trace/paged_engine_burst",
            "completed": len(fin), "submitted": n_requests,
            "peak_block_util": round(eng.stats["peak_block_util"], 3),
            "preemptions": eng.stats["preemptions"],
            "prefill_chunks": eng.stats["chunks"],
            "fp16_fraction": round(ctrl.fp16_time_fraction(), 3)}


if __name__ == "__main__":
    for r in run():
        print(r)
