"""Paper Fig. 1b: SLO compliance under a bursty trace — FP16 vs FP8 vs
dual-precision (NestedFP) on the Azure-like arrival process — plus three
functional paged-engine runs:

* `measured_paged_engine` — a burst into a deliberately scarce pool
  (block utilization, preemptions, prefix-cache hit rate);
* `measured_mla_engine` — the same burst over an MLA (deepseek-class)
  model whose latent planes page through the same BlockManager;
* `measured_gemma3_engine` — long prompts (>= 4x the sliding window)
  through a gemma3-style local:global model: local-layer blocks are
  window-slide reclaimed mid-generation, so the row tracks honest pool
  headroom (reclaimed blocks, peak utilization) for the dominant
  open-weights dense family;
* `measured_engine_trace` — the Azure-like trace driven through the REAL
  engine with request submission gated on `Request.arrival_s` against
  the engine clock (the modeled rows abstract arrivals away; the old
  burst row ignored them entirely). Reports TTFT/TPOT measured against
  the trace's arrival times, plus prefix hit-rate and blocks saved —
  every request shares a system-prompt prefix, the dominant real-world
  reuse pattern.
"""

from __future__ import annotations

import collections

from repro.serving import simulate, trace


def run() -> list[dict]:
    reqs = trace.azure_like(duration_s=60, mean_rate=5.05, seed=7,
                            prompt_len=256, max_new=512)
    cost = simulate.CostModel(fixed_ms=2.0, weight_read_ms_fp16=16.0,
                              weight_read_ms_fp8=8.0, kv_ms_per_ktoken=0.002,
                              compute_ms_per_token_fp16=0.055,
                              compute_ms_per_token_fp8=0.0275)
    rows = []
    for pol in ("fp16", "fp8", "dual"):
        r = simulate.simulate(reqs, cost, policy=pol)
        d = r.row()
        d["name"] = f"slo_trace/{pol}"
        rows.append(d)
    rows.append(measured_paged_engine())
    rows.append(measured_mla_engine())
    rows.append(measured_gemma3_engine())
    rows.append(measured_engine_trace())
    rows.extend(measured_router_chaos())
    return rows


def _tiny_engine(arch: str = "qwen1.5-0.5b", **kw):
    import jax

    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine

    cfg = ARCHS[arch].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, to_serving(params), **kw)


def measured_paged_engine(n_requests: int = 12) -> dict:
    """Burst n_requests into a deliberately scarce paged pool: admission
    is block-driven, decode growth preempts the youngest sequences, and
    every request still completes (recompute preemption)."""
    import numpy as np

    from repro.core.policy import DualPrecisionController, SLOConfig
    from repro.serving.engine import Request

    ctrl = DualPrecisionController(SLOConfig(tpot_ms=33.3),
                                  fp16_ms_per_token=0.2,
                                  fp8_ms_per_token=0.1)
    rng = np.random.RandomState(1)
    eng = _tiny_engine(n_slots=6, capacity=64, controller=ctrl,
                       block_size=8, n_blocks=24, chunk_tokens=64)
    for i in range(n_requests):
        eng.submit(Request(f"r{i}", list(rng.randint(1, 400, 24)),
                           max_new=12))
    fin = eng.run()
    ps = eng.prefix_cache_stats()
    return {"name": "slo_trace/paged_engine_burst",
            "completed": len(fin), "submitted": n_requests,
            "peak_block_util": round(eng.stats["peak_block_util"], 3),
            "preemptions": eng.stats["preemptions"],
            "prefill_chunks": eng.stats["chunks"],
            "prefix_hit_rate": round(ps["hit_rate"], 3),
            "blocks_saved": ps["blocks_saved"],
            "fp16_fraction": round(ctrl.fp16_time_fraction(), 3)}


def measured_mla_engine(n_requests: int = 8) -> dict:
    """Same scarce-pool burst over an MLA (deepseek-class) model: the
    latent `c_kv`+`k_rope` planes page through the same BlockManager, so
    the row tracks latent-block utilization, preemptions, and prefix
    hit-rate over latent blocks — the perf trajectory for the families
    the legacy fixed-slot path used to hide from the controller."""
    import numpy as np

    from repro.core.policy import DualPrecisionController, SLOConfig
    from repro.serving.engine import Request

    ctrl = DualPrecisionController(SLOConfig(tpot_ms=33.3),
                                   fp16_ms_per_token=0.2,
                                   fp8_ms_per_token=0.1)
    rng = np.random.RandomState(1)
    eng = _tiny_engine("deepseek-v3-671b", n_slots=6, capacity=64,
                       controller=ctrl, block_size=8, n_blocks=24,
                       chunk_tokens=64)
    sys_prompt = list(rng.randint(1, 400, 8))
    for i in range(n_requests):
        eng.submit(Request(f"r{i}",
                           sys_prompt + list(rng.randint(1, 400, 16)),
                           max_new=12))
    fin = eng.run()
    ps = eng.prefix_cache_stats()
    return {"name": "slo_trace/mla_engine_burst",
            "completed": len(fin), "submitted": n_requests,
            "peak_block_util": round(eng.stats["peak_block_util"], 3),
            "preemptions": eng.stats["preemptions"],
            "prefill_chunks": eng.stats["chunks"],
            "prefix_hit_rate": round(ps["hit_rate"], 3),
            "blocks_saved": ps["blocks_saved"],
            "fp16_fraction": round(ctrl.fp16_time_fraction(), 3)}


def measured_gemma3_engine(n_requests: int = 6) -> dict:
    """Sliding-window burst: gemma3-style 1:1 reduced local:global
    layout (window 19) with prompts >= 4x the window, so steady-state
    decode continuously slide-frees local-layer blocks back to the
    pool. The row tracks the reclaimed-block count and the honest peak
    utilization the controller's `free_block_frac` trigger now sees —
    the no-reclamation layout would pin every local block forever."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.RandomState(2)
    sys_prompt = list(rng.randint(1, 400, 24))
    eng = _tiny_engine("gemma3-1b", n_slots=4, capacity=128,
                       forced_mode="fp16", block_size=8, chunk_tokens=96)
    for i in range(n_requests):
        eng.submit(Request(f"r{i}",
                           sys_prompt + list(rng.randint(1, 400, 64)),
                           max_new=12))
    fin = eng.run()
    ps = eng.prefix_cache_stats()
    return {"name": "slo_trace/gemma3_window_burst",
            "completed": len(fin), "submitted": n_requests,
            "window_reclaimed_blocks": eng.stats["window_reclaimed_blocks"],
            "peak_block_util": round(eng.stats["peak_block_util"], 3),
            "preemptions": eng.stats["preemptions"],
            "prefill_chunks": eng.stats["chunks"],
            "prefix_hit_rate": round(ps["hit_rate"], 3),
            "blocks_saved": ps["blocks_saved"]}


def measured_engine_trace(duration_s: float = 3.0, mean_rate: float = 3.0,
                          prompt_len: int = 24, max_new: int = 8,
                          system_prompt_len: int = 16, seed: int = 7) -> dict:
    """Drive an Azure-like arrival trace through the REAL paged engine:
    submission is gated on the engine clock (a request enters the queue
    only once its `arrival_s` has passed), so TTFT/TPOT are measured
    against true arrival times rather than a burst-at-zero fiction.
    Idle gaps (nothing queued, active, or prefilling) are fast-forwarded
    by shifting the trace origin — standard open-loop replay. Every
    prompt starts with a shared system prefix so the run also measures
    prefix-cache hit rate under realistic traffic."""
    import numpy as np

    from repro.serving.engine import Request

    treqs = trace.azure_like(duration_s=duration_s, mean_rate=mean_rate,
                             seed=seed, prompt_len=prompt_len,
                             max_new=max_new)
    rng = np.random.RandomState(seed)
    sys_prompt = list(rng.randint(1, 400, system_prompt_len))
    eng = _tiny_engine(n_slots=8, capacity=128, forced_mode="fp16",
                       block_size=8, chunk_tokens=128)
    pending = collections.deque(
        (tr, sys_prompt + list(rng.randint(1, 400, max(1, tr.prompt_len))),
         max(1, tr.max_new)) for tr in treqs)
    t0 = eng.clock()
    submitted = []
    while pending or eng.queue or eng.active or eng.prefilling:
        if pending and not (eng.queue or eng.active or eng.prefilling):
            # idle: fast-forward the trace origin to the next arrival
            t0 = min(t0, eng.clock() - pending[0][0].arrival_s)
        now = eng.clock() - t0
        while pending and pending[0][0].arrival_s <= now:
            tr, toks, mnew = pending.popleft()
            req = Request(f"t{len(submitted)}", toks, max_new=mnew,
                          arrival_s=t0 + tr.arrival_s)
            submitted.append(req)
            eng.submit(req)
        eng.step()
    ttft = np.asarray([r.first_token_s - r.arrival_s for r in submitted])
    tpot = np.concatenate([np.diff(r.token_times) for r in submitted
                           if len(r.token_times) > 1])
    ps = eng.prefix_cache_stats()
    return {"name": "slo_trace/engine_trace_arrivals",
            "completed": len(eng.finished), "submitted": len(submitted),
            "p50_ttft_ms": round(float(np.percentile(ttft, 50)) * 1e3, 1),
            "p90_ttft_ms": round(float(np.percentile(ttft, 90)) * 1e3, 1),
            "p90_tpot_ms": round(float(np.percentile(tpot, 90)) * 1e3, 1)
            if tpot.size else 0.0,
            "prefill_chunks": eng.stats["chunks"],
            "chunk_tokens": eng.stats["chunk_tokens"],
            "prefix_hit_rate": round(ps["hit_rate"], 3),
            "blocks_saved": ps["blocks_saved"],
            "peak_block_util": round(eng.stats["peak_block_util"], 3)}


def _chaos_run(degrade: bool, *, duration_s: float, mean_rate: float,
               seed: int, kill_step: int, slo_tpot_ms: float):
    """One 3-replica chaos run over a shared VirtualClock: arrival-gated
    submission, a planned kill of replica 0 mid-burst, and per-step
    clock advance from the modeled cost of the slowest replica — so the
    degrade-vs-no-degrade comparison is an exact function of the
    schedule, not host noise."""
    import numpy as np

    from repro.core.policy import DegradePolicy
    from repro.serving.engine import Request
    from repro.serving.faults import FaultEvent, FaultPlan
    from repro.serving.router import Router, StepCostModel, VirtualClock

    vc = VirtualClock()
    engines = [_tiny_engine(n_slots=8, capacity=192, clock=vc,
                            block_size=16, n_blocks=24, chunk_tokens=64)
               for _ in range(3)]
    policy = DegradePolicy(force_fp8=True, shed_budget_tokens=2048,
                           restore_scale=0.5, hysteresis_steps=8) \
        if degrade else None
    router = Router(engines,
                    policy=policy,
                    plan=FaultPlan([FaultEvent(kill_step, 0, "kill")]),
                    clock=vc,
                    cost_model=StepCostModel(
                        fixed_ms=2.0,
                        ms_per_token={"fp16": 4.0, "fp8": 2.0}),
                    affinity_blocks=1, balance_slack_tokens=96)
    treqs = trace.azure_like(duration_s=duration_s, mean_rate=mean_rate,
                             seed=seed, prompt_len=12, max_new=40)
    rng = np.random.RandomState(seed)
    sys_prompt = list(rng.randint(1, 400, 8))
    pending = collections.deque(
        (tr, sys_prompt + list(rng.randint(1, 400, max(1, tr.prompt_len))),
         max(1, tr.max_new)) for tr in treqs)
    submitted = []
    while pending or router.in_flight():
        if pending and not router.in_flight():
            vc.advance(max(0.0, pending[0][0].arrival_s - vc.now))
        while pending and pending[0][0].arrival_s <= vc.now:
            tr, toks, mnew = pending.popleft()
            req = Request(f"t{len(submitted)}", toks, max_new=mnew,
                          arrival_s=tr.arrival_s)
            submitted.append(req)
            router.submit(req)
        router.step()
    done = {r.request_id for r in router.finished}
    ttft = np.asarray([r.first_token_s - r.arrival_s for r in submitted
                       if r.request_id in done])
    tpot = np.concatenate([np.diff(r.token_times) for r in submitted
                           if r.request_id in done
                           and len(r.token_times) > 1])
    return {"stats": router.stats(),
            "submitted": len(submitted),
            "p90_ttft_ms": round(float(np.percentile(ttft, 90)) * 1e3, 1),
            "p90_tpot_ms": round(float(np.percentile(tpot, 90)) * 1e3, 1),
            "slo_tpot_ms": slo_tpot_ms}


def measured_router_chaos(duration_s: float = 2.0, mean_rate: float = 7.0,
                          seed: int = 11, kill_step: int = 14,
                          slo_tpot_ms: float = 33.3) -> list[dict]:
    """Kill 1 of 3 replicas mid-burst, twice: once with the
    DegradePolicy driving FP8 on the survivors and once without. Three
    rows: the full chaos accounting, the conservation invariant
    (`failover_lost_requests` — MUST be 0), and the SLO comparison
    (`degraded_p90_tpot`: degrade holds p90 TPOT within the SLO where
    the no-degrade router violates it)."""
    deg = _chaos_run(True, duration_s=duration_s, mean_rate=mean_rate,
                     seed=seed, kill_step=kill_step,
                     slo_tpot_ms=slo_tpot_ms)
    raw = _chaos_run(False, duration_s=duration_s, mean_rate=mean_rate,
                     seed=seed, kill_step=kill_step,
                     slo_tpot_ms=slo_tpot_ms)
    ds, rs = deg["stats"], raw["stats"]
    rows = [
        {"name": "router/chaos_failover",
         "replicas": 3, "kill_step": kill_step,
         "submitted": ds["submitted"], "completed": ds["completed"],
         "shed": ds["shed"], "kills": ds["kills"],
         "failovers": ds["failovers"],
         "failover_requests": ds["failover_requests"],
         "failover_restored_tokens": ds["failover_restored_tokens"],
         "failover_recomputed_tokens": ds["failover_recomputed_tokens"],
         "degrade_fp8_steps": ds["degrade_fp8_steps"],
         "fp8_dwell": ds["fp8_dwell"],
         "p90_ttft_ms": deg["p90_ttft_ms"]},
        {"name": "router/failover_lost_requests",
         "value": max(ds["lost"], rs["lost"]),
         "degrade_lost": ds["lost"], "no_degrade_lost": rs["lost"]},
        {"name": "router/degraded_p90_tpot",
         "value": deg["p90_tpot_ms"], "slo_tpot_ms": slo_tpot_ms,
         "no_degrade_p90_tpot_ms": raw["p90_tpot_ms"],
         "holds_slo": bool(deg["p90_tpot_ms"] <= slo_tpot_ms),
         "no_degrade_holds": bool(raw["p90_tpot_ms"] <= slo_tpot_ms)},
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
