"""Paper Fig. 1b: SLO compliance under a bursty trace — FP16 vs FP8 vs
dual-precision (NestedFP) on the Azure-like arrival process."""

from __future__ import annotations

from repro.serving import simulate, trace


def run() -> list[dict]:
    reqs = trace.azure_like(duration_s=60, mean_rate=5.05, seed=7,
                            prompt_len=256, max_new=512)
    cost = simulate.CostModel(fixed_ms=2.0, weight_read_ms_fp16=16.0,
                              weight_read_ms_fp8=8.0, kv_ms_per_ktoken=0.002,
                              compute_ms_per_token_fp16=0.055,
                              compute_ms_per_token_fp8=0.0275)
    rows = []
    for pol in ("fp16", "fp8", "dual"):
        r = simulate.simulate(reqs, cost, policy=pol)
        d = r.row()
        d["name"] = f"slo_trace/{pol}"
        rows.append(d)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
