"""Paper Table 3: layer-wise NestedFP applicability across models.

Real pretrained checkpoints are unavailable offline, so we measure on
(a) initialized models of every assigned arch (init scale ~ 1/sqrt(d) —
all applicable, the trivial case), and (b) synthetic heavy-tailed weight
ensembles calibrated to the paper's reported per-model abs-max statistics
(Llama-3.1-8B max<1.75 ... Gemma3 max 26.25), which reproduces the paper's
applicability ordering.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.core import nestedfp as nf

# (model, sigma, abs_max_clip, n_layers) calibrated to paper Table 3 notes
PAPER_PROFILES = [
    ("llama3.1-8b-like", 0.02, 1.2, 224),      # 100% applicable
    ("mistral-nemo-like", 0.02, 1.5, 280),     # 100%
    ("qwen3-32b-like", 0.03, 2.6, 448),        # ~97.8%: few spiky layers
    ("phi4-like", 0.03, 2.9, 160),             # ~91%
    ("llama3.1-70b-like", 0.025, 93.0, 560),   # 93.4%: rare extreme layers
    ("gemma3-27b-like", 0.05, 26.25, 759),     # ~82%: multimodal projections
]


def synthetic_layer(rng, sigma, abs_max_clip, spiky: bool):
    w = rng.standard_normal((256, 256)).astype(np.float32) * sigma
    if spiky:
        idx = rng.randint(0, w.size, 4)
        w.flat[idx] = rng.uniform(1.8, abs_max_clip, 4) * rng.choice([-1, 1], 4)
    return w.astype(np.float16)


def run() -> list[dict]:
    rows = []
    rng = np.random.RandomState(0)
    for name, sigma, mx, n_layers in PAPER_PROFILES:
        spike_frac = {"llama3.1-8b-like": 0.0, "mistral-nemo-like": 0.0,
                      "qwen3-32b-like": 0.022, "phi4-like": 0.0875,
                      "llama3.1-70b-like": 0.066,
                      "gemma3-27b-like": 0.19}[name]
        applicable = 0
        for i in range(n_layers):
            w = synthetic_layer(rng, sigma, mx, rng.rand() < spike_frac)
            applicable += bool(nf.is_applicable(jax.numpy.asarray(w)))
        rows.append({"name": f"applicability/{name}",
                     "applicable": applicable, "total": n_layers,
                     "fraction": applicable / n_layers})

    # initialized assigned archs (every linear tensor checked)
    from repro.configs import ARCHS
    from repro.models import model as M
    for arch in ("qwen1.5-0.5b", "granite-moe-3b-a800m", "mamba2-2.7b"):
        cfg = ARCHS[arch].reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        n_app = n_tot = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            if hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.size > 1024:
                n_tot += 1
                n_app += bool(nf.is_applicable(leaf.astype(jax.numpy.float16)))
        rows.append({"name": f"applicability/init-{arch}",
                     "applicable": n_app, "total": n_tot,
                     "fraction": n_app / max(n_tot, 1)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
