"""Paper Tables 1 & 2: NestedFP8 accuracy vs the per-channel FP8 baseline.

Two levels of evidence (downstream task suites are unavailable offline):
 1. Tensor-level quantization error (MSE / SQNR / cosine) of
    FP8(B) = per-channel-absmax E4M3 vs FP8(N) = NestedFP global 2^8 —
    across weight distributions spanning the models' observed ranges.
 2. Model-level: a trained tiny LM evaluated at FP16 / FP8(B) / FP8(N):
    eval CE loss deltas mirror the paper's Table 1/2 structure
    (FP8 slightly worse than FP16; FP8(N) ~ FP8(B)).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import nestedfp as nf
from repro.core import quant


def tensor_level() -> list[dict]:
    rng = np.random.RandomState(1)
    rows = []
    for name, gen in [
        ("gauss_s0.02", lambda: rng.standard_normal((512, 512)) * 0.02),
        ("gauss_s0.2", lambda: rng.standard_normal((512, 512)) * 0.2),
        ("heavy_tail", lambda: rng.standard_t(4, (512, 512)) * 0.05),
        ("near_limit", lambda: rng.uniform(-1.7, 1.7, (512, 512))),
    ]:
        w = jnp.asarray(np.clip(gen(), -1.75, 1.75).astype(np.float16))
        # FP8(N): upper byte at global scale 2^-8
        u, _ = nf.encode(w)
        w_n = nf.fp8_dequant(u, jnp.float32)
        m_n = quant.quant_error_metrics(w, w_n)
        # FP8(B): per-channel absmax
        q, s = quant.quantize_weight_per_channel(w)
        w_b = q.astype(jnp.float32) * s
        m_b = quant.quant_error_metrics(w, w_b)
        rows.append({"name": f"quant/{name}",
                     "sqnr_nested_db": round(m_n["sqnr_db"], 2),
                     "sqnr_baseline_db": round(m_b["sqnr_db"], 2),
                     "cos_nested": round(m_n["cosine"], 6),
                     "cos_baseline": round(m_b["cosine"], 6)})
    return rows


def model_level(steps: int = 40) -> list[dict]:
    from repro.configs import ARCHS
    from repro.data.pipeline import DataConfig, SyntheticLM, microbatch_split
    from repro.launch.steps import make_train_step
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.models.layers import Runtime
    from repro.optim import adamw

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=2e-3, total_steps=steps, warmup_steps=4)
    opt = adamw.init_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
    for batch in data.batches(steps):
        b = microbatch_split({k: jnp.asarray(v) for k, v in batch.items()}, 2)
        params, opt, _ = step(params, opt, b)

    eval_batches = list(SyntheticLM(
        cfg, DataConfig(seq_len=64, global_batch=8, seed=999)).batches(4))

    # nfp: hot-path
    def eval_loss(p, rt):
        # accumulate ON DEVICE: the old per-batch float(...) forced a
        # host sync after every dispatch, serializing the eval loop
        # (repro-lint NFP001); callers scalarize the mean once
        tot = jnp.zeros((), jnp.float32)
        for batch in eval_batches:
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            tot = tot + M.train_loss(rt, p, cfg, b)[0]
        return tot / len(eval_batches)

    f16 = float(eval_loss(params, Runtime(mode="train", dtype=jnp.float32)))
    sp = to_serving(params)
    n16 = float(eval_loss(sp, Runtime(mode="fp16", backend="ref",
                                      dtype=jnp.float32)))
    n8 = float(eval_loss(sp, Runtime(mode="fp8", backend="ref",
                                     dtype=jnp.float32)))

    # baseline FP8(B): per-channel weight quant materialized, plain matmul
    def quantize_tree(tree):
        def q(p):
            if hasattr(p, "ndim") and p.ndim == 2 and p.size > 4096:
                qq, s = quant.quantize_weight_per_channel(p.astype(jnp.float16))
                return (qq.astype(jnp.float32) * s).astype(jnp.float32)
            return p
        return jax.tree.map(q, tree)

    b8 = float(eval_loss(quantize_tree(params),
                         Runtime(mode="train", dtype=jnp.float32)))
    return [{"name": "accuracy/eval_ce",
             "fp16": round(f16, 4), "nested_fp16": round(n16, 4),
             "fp8_baseline": round(b8, 4), "nested_fp8": round(n8, 4),
             "delta_nested_fp8_vs_fp16": round(n8 - f16, 4),
             "delta_baseline_fp8_vs_fp16": round(b8 - f16, 4)}]


def run() -> list[dict]:
    return tensor_level() + model_level()


if __name__ == "__main__":
    for r in run():
        print(r)
