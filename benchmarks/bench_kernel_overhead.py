"""Paper Fig. 7: NestedFP16 kernel overhead vs the vanilla f16 kernel.

On CPU we cannot measure MXU wall-time, so the comparison is:
  * STRUCTURAL: per-weight work added by reconstruction (VPU int ops) and
    HBM bytes moved (equal by construction — the paper's key property),
    derived from the kernel jaxprs;
  * interpret-mode wall time ratio as a sanity signal only (Python
    executes the kernel body; both kernels share the same harness).

Shapes: the paper's (N,K) GEMMs from its four models, scaled to CPU-
tractable sizes with M swept like Fig. 7a.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import nestedfp as nf
from repro.kernels.f16_matmul import f16_matmul
from repro.kernels.nestedfp16_matmul import nestedfp16_matmul
from repro.roofline import flops as fcount

# paper models' GEMM shapes (N, K), divided by 16 for interpret tractability
PAPER_SHAPES = {
    "llama31_qkv": (6144 // 16 * 2, 4096 // 16 * 2),
    "llama31_mlp": (28672 // 16, 4096 // 16 * 2),
    "phi4_qkv": (7680 // 16 * 2, 5120 // 16 * 2),
    "mistral_small_mlp": (65536 // 16, 5120 // 16 * 2),
}
MS = (128, 256, 512)


def _structural(m, k, n) -> dict:
    x = jax.ShapeDtypeStruct((m, k), jnp.float16)
    u = jax.ShapeDtypeStruct((k, n), jnp.uint8)
    w = jax.ShapeDtypeStruct((k, n), jnp.float16)
    f_nested = fcount.count_step_flops(
        lambda a, b, c: nestedfp16_matmul(a, b, c, block=(128, 128, 128),
                                          interpret=True), x, u, u)
    f_plain = fcount.count_step_flops(
        lambda a, b: f16_matmul(a, b, block=(128, 128, 128), interpret=True),
        x, w)
    return {"flops_nested": f_nested, "flops_plain": f_plain,
            "vpu_overhead_frac": (f_nested - f_plain) / f_plain,
            "hbm_weight_bytes_nested": 2 * k * n,
            "hbm_weight_bytes_plain": 2 * k * n}


# nfp: hot-path
def _timed(fn, *args, reps=3) -> float:
    # nfp: ignore[NFP001] warmup fence: exclude compile time from the measurement
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        # nfp: ignore[NFP001] timing fence: the sync IS what is measured
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def block_table_overhead(n_slots: int = 64, blocks_per_seq: int = 64,
                         reps: int = 200) -> dict:
    """Per-decode-step cost of materializing the (n_slots, max_blocks)
    block-table array: the old code rebuilt it in Python (np.full + row
    fills) every step; BlockManager now keeps one persistent array
    updated incrementally in ensure/release/fork, so `tables()` is a
    return of a maintained buffer."""
    from repro.serving.kvcache import TRASH_BLOCK, BlockManager

    n_blocks = n_slots * blocks_per_seq
    bm = BlockManager(n_slots, 16, n_blocks, blocks_per_seq)
    for i in range(n_slots):
        idx = bm.try_allocate(f"r{i}", 16 * blocks_per_seq, 0)
        bm.ensure(idx, 16 * blocks_per_seq)

    def rebuild():                      # the replaced per-step code path
        rows = []
        for i in range(n_slots):
            row = np.full(blocks_per_seq, TRASH_BLOCK, np.int32)
            seq = bm.seqs[i]
            if seq is not None:
                row[: len(seq.blocks)] = seq.blocks
            rows.append(row)
        return np.stack(rows)

    t0 = time.perf_counter()
    for _ in range(reps):
        rebuild()
    t_rebuild = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        bm.tables()
    t_incr = (time.perf_counter() - t0) / reps * 1e6
    assert (rebuild() == bm.tables()).all()
    return {"name": f"kernel_overhead/block_tables_{n_slots}x{blocks_per_seq}",
            "us_rebuild_per_step": round(t_rebuild, 2),
            "us_incremental_per_step": round(t_incr, 2),
            "speedup": round(t_rebuild / max(t_incr, 1e-9), 1)}


def engine_dispatch_overhead(n_prefill: int = 4, decode_steps: int = 8
                             ) -> list[dict]:
    """One-dispatch engine accounting: jitted dispatches per step and
    host->device bytes per step through the REAL paged engine.

    * `prefill_dispatches_per_step` must be 1 no matter how many
      sequences are prefilling concurrently (the batched ragged fusion;
      asserted by the CI bench smoke).
    * `table_h2d_bytes_per_decode_step` is the incremental block-table
      flush — a few table entries, not the full (G, n_slots, MB) array
      the engine used to re-upload every step.
    """
    import jax

    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.RandomState(0)

    def fresh_engine():
        return Engine(cfg, sparams, n_slots=max(8, 2 * n_prefill),
                      capacity=128, forced_mode="fp16", chunk_tokens=512,
                      prefix_cache=False)

    # --- prefill fusion: n_prefill concurrent prompts, ONE step --------------
    eng = fresh_engine()
    for i in range(n_prefill):
        eng.submit(Request(f"p{i}", list(rng.randint(1, 200, 40)),
                           max_new=12))      # 40+12 crosses a block edge
    eng.step()                               # all n_prefill chunks planned
    assert eng.stats["chunks"] == n_prefill, eng.stats
    prefill_dispatches = eng.stats["prefill_dispatches"]

    # --- steady-state decode: incremental table flush bytes ------------------
    b0 = eng.blocks.table_h2d_bytes + eng.stats["h2d_bytes"]
    t0 = eng.blocks.table_h2d_bytes
    it0 = eng.iteration
    for _ in range(decode_steps):
        if not (eng.active or eng.prefilling or eng.queue):
            break
        eng.step()
    steps = max(eng.iteration - it0, 1)
    table_inc = (eng.blocks.table_h2d_bytes - t0) / steps
    h2d_step = (eng.blocks.table_h2d_bytes + eng.stats["h2d_bytes"] - b0) \
        / steps
    full = eng.blocks.group_tables().nbytes
    return [
        {"name": "engine_dispatch/prefill_dispatches_per_step",
         "value": prefill_dispatches, "concurrent_prefills": n_prefill,
         "chunks_fused": n_prefill},
        {"name": "engine_dispatch/table_h2d_bytes_per_decode_step",
         "value": round(table_inc, 1), "full_table_bytes": full,
         "saving": round(1 - table_inc / full, 4)},
        {"name": "engine_dispatch/h2d_bytes_per_decode_step",
         "value": round(h2d_step, 1),
         "note": "tokens+offsets+lens int32 rows + incremental table flush"},
    ]


def speculation_overhead(max_new: int = 16) -> list[dict]:
    """Speculative-decoding payout on a repetitive-suffix trace: tiny
    random models degenerate into looping continuations under greedy
    decode, which is exactly the regime prompt-lookup drafting serves —
    so the n-gram proposer's accepted tokens per dispatch is measurable
    without a trained checkpoint. The CI bench smoke asserts
    `tokens_accepted_per_dispatch > 1` here (and == 1.0 with speculation
    off), alongside the unchanged one-dispatch prefill row. Every ratio
    reported is guarded: a trace with zero decode rows or zero drafts
    reports 0.0 rather than raising."""
    import jax

    from repro.configs import ARCHS
    from repro.core.policy import SpeculationConfig
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    # repetitive suffixes: greedy decode settles into a loop the
    # single-token-suffix matcher (ngram_min=1) drafts ahead of
    prompts = [[5, 6, 7, 8] * 6, [11, 12, 13] * 8]

    def serve(spec):
        eng = Engine(cfg, sparams, n_slots=4, capacity=128,
                     forced_mode="fp16", speculate=spec)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"s{i}", list(p), max_new=max_new))
        outs = [r.output for r in sorted(eng.run(),
                                         key=lambda r: r.request_id)]
        return outs, eng

    outs_off, eng_off = serve(None)
    outs_on, eng_on = serve(SpeculationConfig(ngram_min=1))
    ss, base = eng_on.spec_stats(), eng_off.spec_stats()
    return [
        {"name": "spec/tokens_accepted_per_dispatch",
         "value": round(ss["tokens_accepted_per_dispatch"], 3),
         "baseline_off": round(base["tokens_accepted_per_dispatch"], 3),
         "acceptance_rate": round(ss["acceptance_rate"], 3),
         "drafted": ss["drafted"], "accepted": ss["accepted"],
         "bit_exact_vs_off": outs_on == outs_off},
        {"name": "spec/decode_dispatch_saving",
         "decode_dispatches_on": eng_on.stats["decode_dispatches"],
         "decode_dispatches_off": eng_off.stats["decode_dispatches"],
         "saving": round(
             1 - eng_on.stats["decode_dispatches"]
             / eng_off.stats["decode_dispatches"], 4)
         if eng_off.stats["decode_dispatches"] else 0.0},
    ]


def restore_overhead(prefix_len: int = 512, n_reqs: int = 3,
                     max_new: int = 4) -> list[dict]:
    """Tiered-KV payout on a shared-system-prompt burst: an engine whose
    prefix store was persisted by an earlier process restores the
    system-prompt blocks from the host tier (a few scatter uploads),
    while the recompute baseline pays the full chunked prefill of the
    shared prefix. A long prefix with a small `chunk_tokens` makes the
    recompute side pay several prefill dispatches of real compute, the
    regime the ROADMAP's restore-vs-recompute row targets; the CI bench
    smoke asserts `speedup > 1`. Both engines are warmed on a
    same-shaped burst with a DIFFERENT prefix first so executable
    compilation stays out of the measurement."""
    import tempfile

    import jax

    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.RandomState(0)
    sysp = list(rng.randint(1, 200, prefix_len))
    warm_sysp = list(rng.randint(1, 200, prefix_len))

    def burst(tag, prefix):
        return [Request(f"{tag}{i}",
                        prefix + list(np.random.RandomState(50 + i)
                                      .randint(1, 200, 8)), max_new)
                for i in range(n_reqs)]

    def mk(persist=None):
        return Engine(cfg, sparams, n_slots=4, capacity=prefix_len + 64,
                      block_size=16, chunk_tokens=128, forced_mode="fp16",
                      persist_dir=persist)

    def warm_scatter(e):
        # compile the restore-upload executable outside the timed burst:
        # a scatter aimed entirely at the trash block writes no live data
        nb = _pow2_blocks = 1
        while _pow2_blocks < -(-prefix_len // 16):
            _pow2_blocks *= 2
            nb = _pow2_blocks
        ids = np.zeros(nb, np.int32)             # TRASH_BLOCK
        vals = {}
        for p in e.desc.planes:
            vals[p.name] = jnp.zeros(
                (p.n_layers, nb, 16) + tuple(p.token_shape),
                np.dtype(p.dtype))
        e.caches = e._scatter_hi[0](e.caches, jnp.asarray(ids), vals)

    def serve(e, tag, prefix):
        for r in burst(tag, prefix):
            e.submit(r)
        t0 = time.perf_counter()
        e.run()
        return time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        seed = mk(persist=d)
        serve(seed, "seed", sysp)
        entries = seed.save_prefix_store()
        t_each = {}
        for kind in ("restore", "recompute"):
            e = mk(persist=d if kind == "restore" else None)
            serve(e, "warm", warm_sysp)          # compile + warm caches
            if kind == "restore":
                warm_scatter(e)
            t_each[kind] = serve(e, "x", sysp)
            if kind == "restore":
                tiered = e.tiered_stats()
        assert tiered["restored_blocks"] > 0, tiered
    return [{"name": "tiered/restore_vs_recompute",
             "s_restore": round(t_each["restore"], 4),
             "s_recompute": round(t_each["recompute"], 4),
             "speedup": round(t_each["recompute"]
                              / max(t_each["restore"], 1e-9), 3),
             "prefix_len": prefix_len, "persisted_entries": entries,
             "restored_blocks": tiered["restored_blocks"],
             "restored_bytes": tiered["restored_bytes"],
             "restore_fallbacks": tiered["restore_fallbacks"]}]


def run(quick: bool = True) -> list[dict]:
    rows = [block_table_overhead()]
    rows += engine_dispatch_overhead()
    rows += speculation_overhead()
    rows += restore_overhead()
    rng = np.random.RandomState(0)
    shapes = list(PAPER_SHAPES.items())[:2] if quick else list(PAPER_SHAPES.items())
    ms = MS[:2] if quick else MS
    for name, (n, k) in shapes:
        for m in ms:
            x = jnp.asarray(rng.uniform(-1, 1, (m, k)).astype(np.float16))
            w = jnp.asarray(rng.uniform(-1.5, 1.5, (k, n)).astype(np.float16))
            u, l = nf.encode(w)
            t_plain = _timed(lambda a, b: f16_matmul(
                a, b, block=(128, 128, 128), interpret=True), x, w)
            t_nest = _timed(lambda a, b, c: nestedfp16_matmul(
                a, b, c, block=(128, 128, 128), interpret=True), x, u, l)
            s = _structural(m, k, n)
            rows.append({
                "name": f"kernel_overhead/{name}_M{m}",
                "us_plain_interp": round(t_plain, 1),
                "us_nested_interp": round(t_nest, 1),
                "interp_overhead": round(t_nest / t_plain - 1, 4),
                "vpu_overhead_frac": round(s["vpu_overhead_frac"], 4),
                "hbm_bytes_equal": s["hbm_weight_bytes_nested"]
                                   == s["hbm_weight_bytes_plain"],
            })
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
