"""Deliverable (g): aggregate the dry-run artifacts into the §Roofline
table — three terms, dominant bottleneck, MODEL_FLOPS ratio per
(arch × shape × mesh)."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(art_dir: str = ART) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: list[dict]) -> list[dict]:
    out = []
    for r in rows:
        base = {"name": f"roofline/{r['arch']}__{r['shape']}__{r['mesh']}"
                        f"__{r.get('mode', 'fp16')}"}
        if r.get("status") == "skipped":
            out.append({**base, "status": "skipped", "reason": r["reason"]})
            continue
        if r.get("status") != "ok":
            out.append({**base, "status": r.get("status"),
                        "error": r.get("error", "")[:120]})
            continue
        t = r["roofline"]
        out.append({
            **base, "status": "ok",
            "compute_s": f"{t['compute_s']:.3e}",
            "memory_s": f"{t['memory_s']:.3e}",
            "collective_s": f"{t['collective_s']:.3e}",
            "dominant": t["dominant"].replace("_s", ""),
            "bound_step_s": f"{t['bound_step_s']:.3e}",
            "useful_ratio": round(t["useful_ratio"], 3),
            "peak_gib": round(r["memory"]["peak_gib"], 2),
            "fits_16gib": r["memory"]["peak_gib"] <= 16.0,
        })
    return out


def markdown(rows: list[dict]) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    sk = [r for r in rows if r.get("status") == "skipped"]
    lines = ["| arch__shape__mesh | compute_s | memory_s | collective_s | "
             "dominant | useful | peak GiB | fits |",
             "|---|---|---|---|---|---|---|---|"]
    for r in ok:
        nm = r["name"].replace("roofline/", "").replace("__fp16", "")
        lines.append(f"| {nm} | {r['compute_s']} | {r['memory_s']} | "
                     f"{r['collective_s']} | {r['dominant']} | "
                     f"{r['useful_ratio']} | {r['peak_gib']} | "
                     f"{'✓' if r['fits_16gib'] else '✗'} |")
    if sk:
        lines.append("")
        lines.append("Skipped: " + "; ".join(
            r["name"].replace("roofline/", "") for r in sk))
    return "\n".join(lines)


def run() -> list[dict]:
    return table(load())


if __name__ == "__main__":
    rows = run()
    print(markdown(rows))
