"""Paper Fig. 8 (+ Appendix C): end-to-end serving throughput, FP16 vs
NestedFP16 vs NestedFP8.

Two components:
 1. MEASURED (functional, CPU): engine tokens/s on a tiny model in each
    forced mode — demonstrates the dual-precision engine end to end
    (absolute CPU numbers are not TPU-meaningful).
 2. MODELED (roofline): per-iteration latency for the paper's four models
    from the calibrated cost model — weight traffic halves and MXU rate
    doubles in FP8 — reproducing Fig. 8's speedup structure
    (1.2-1.55x, larger models gain more).
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.serving.simulate import CostModel

PAPER_MODELS = {
    "llama3.1-8b": 8.0e9,
    "mistral-nemo-12b": 12.2e9,
    "phi4-14b": 14.7e9,
    "mistral-small-24b": 23.6e9,
}


def modeled() -> list[dict]:
    rows = []
    for name, n_params in PAPER_MODELS.items():
        cm = CostModel.from_model(n_params, n_chips=1,
                                  kv_bytes_per_token=2 * 32 * 2 * 128 * 8)
        for batch in (32, 128, 512):
            t16 = cm.step_ms("fp16", batch, 0, batch * 0.256)
            t8 = cm.step_ms("fp8", batch, 0, batch * 0.256)
            rows.append({
                "name": f"e2e_modeled/{name}_b{batch}",
                "fp16_ms": round(t16, 3), "nested_fp8_ms": round(t8, 3),
                "fp8_speedup": round(t16 / t8, 3),
                "tok_s_fp16": round(batch / t16 * 1e3, 0),
                "tok_s_fp8": round(batch / t8 * 1e3, 0),
            })
    return rows


MEASURED_FAMILIES = {
    # descriptor families through the ONE paged scheduling path:
    # GQA K/V blocks, MLA latent (c_kv + k_rope) blocks, and gemma3
    # sliding-window GQA (per-layer-group tables with window-slide
    # reclamation of local-layer blocks)
    "gqa": "qwen1.5-0.5b",
    "mla": "deepseek-v3-671b",
    "swa": "gemma3-1b",
}

# prompts >= 4x the reduced gemma3 window (19) so steady-state decode
# actually slides local blocks back to the pool
_PROMPT_LEN = {"swa": 96}


def measured(n_requests: int = 8,
             families=("gqa", "mla", "swa")) -> list[dict]:
    """Paged engine end-to-end in both forced modes, per cache family.
    The scarce-pool run (n_blocks below dense-equivalent) exercises
    decode-growth preemption — the memory-pressure regime the FP16↔FP8
    switch exists for. The MLA rows track the latent-cache serving
    trajectory (block utilization, preemptions, prefix hit-rate over
    latent blocks); the swa (gemma3) rows track sliding-window
    reclamation (blocks returned to the pool mid-generation)."""
    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    rows = []
    for fam in families:
        cfg = ARCHS[MEASURED_FAMILIES[fam]].reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        sparams = to_serving(params)
        plen = _PROMPT_LEN.get(fam, 16)
        for mode in ("fp16", "fp8"):
            for n_blocks, tag in ((None, ""), (12, "_scarce")):
                rng = np.random.RandomState(0)
                eng = Engine(cfg, sparams, n_slots=8, capacity=128,
                             forced_mode=mode, block_size=16,
                             n_blocks=n_blocks)
                for i in range(n_requests):
                    eng.submit(Request(f"r{i}",
                                       list(rng.randint(1, 400, plen)),
                                       max_new=8))
                t0 = time.perf_counter()
                fin = eng.run()
                dt = time.perf_counter() - t0
                toks = sum(len(r.output) for r in fin)
                ps = eng.prefix_cache_stats()
                rows.append({"name": f"e2e_measured_cpu/{fam}_{mode}{tag}",
                             "tokens": toks, "seconds": round(dt, 2),
                             "tok_s": round(toks / dt, 1),
                             "requests": len(fin),
                             "peak_block_util": round(
                                 eng.stats["peak_block_util"], 3),
                             "preemptions": eng.stats["preemptions"],
                             "prefill_chunks": eng.stats["chunks"],
                             "prefix_hit_rate": round(ps["hit_rate"], 3),
                             "blocks_saved": ps["blocks_saved"],
                             "window_reclaimed": eng.stats[
                                 "window_reclaimed_blocks"]})
    return rows


def _per_chip_bytes(tree) -> int:
    """Largest per-device footprint of a sharded pytree: the addressable
    shard shape (NamedSharding.shard_shape) x itemsize per leaf; falls
    back to the full leaf for uncommitted arrays."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "shard_shape"):
            n = 1
            for d in sh.shard_shape(leaf.shape):
                n *= d
            total += n * leaf.dtype.itemsize
        else:
            total += leaf.nbytes
    return total


def mesh_scaling(sizes=(1, 4)) -> list[dict]:
    """Tensor-parallel serving (Engine(mesh=...)): per-chip HBM bytes for
    weights + KV pool and per-step wall latency at mesh 1 vs 4. The
    memory rows are the point — params and the head-sharded pool must
    shrink ~linearly with mesh size; CPU step latency is recorded for the
    dispatch-overhead trend, not as a TPU-meaningful speedup."""
    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.engine import Engine, Request

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    rows = []
    for n in sizes:
        if jax.device_count() < n:
            rows.append({"name": f"e2e_mesh/qwen_fp8_m{n}",
                         "skipped": f"needs {n} devices, "
                                    f"have {jax.device_count()}"})
            continue
        mesh = None if n == 1 else make_serving_mesh(n)
        rng = np.random.RandomState(0)
        eng = Engine(cfg, sparams, n_slots=8, capacity=128,
                     forced_mode="fp8", kv_planar=True, block_size=16,
                     prefix_cache=False, mesh=mesh)
        for i in range(8):
            eng.submit(Request(f"r{i}", list(rng.randint(1, 400, 16)),
                               max_new=8))
        eng.step()                     # all 8 prefills land in this step
        first_step_prefill_dispatches = eng.stats["prefill_dispatches"]
        t0 = time.perf_counter()
        fin = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in fin)
        rows.append({
            "name": f"e2e_mesh/qwen_fp8_m{n}",
            "mesh": n,
            "param_bytes_per_chip": _per_chip_bytes(eng.params),
            "kv_pool_bytes_per_chip": _per_chip_bytes(eng.caches),
            "step_ms": round(dt / max(eng.iteration - 1, 1) * 1e3, 2),
            "tok_s": round(toks / dt, 1),
            "steps": eng.iteration,
            "prefill_dispatches_per_step": first_step_prefill_dispatches,
        })
    return rows


def run() -> list[dict]:
    return modeled() + measured() + mesh_scaling()


if __name__ == "__main__":
    for r in run():
        print(r)
