"""End-to-end driver (deliverable b): train a ~100M-param dense model for a
few hundred steps on the synthetic pipeline, then convert the checkpoint to
NestedFP serving form and generate with the dual-precision engine.

Run: PYTHONPATH=src python examples/train_tiny.py  (~15 min CPU)
Smaller: PYTHONPATH=src python examples/train_tiny.py --fast
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true", help="tiny 2-layer variant")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

if args.fast:
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1.5-0.5b", "--reduced", "--steps",
           str(args.steps or 60), "--batch", "8", "--seq", "128",
           "--ckpt", "out/tiny_ckpt"]
else:
    # ~100M params: qwen1.5-0.5b at 12 layers / d_model 768
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1.5-0.5b", "--layers", "12", "--scale", "0.75",
           "--steps", str(args.steps or 300), "--batch", "16",
           "--seq", "256", "--micro", "2", "--ckpt", "out/tiny_ckpt"]
r = subprocess.run(cmd)
sys.exit(r.returncode)
