"""Dual-precision serving demo (deliverable b): a bursty request stream
through the continuous-batching engine with the SLO controller flipping
between FP16 and FP8 per iteration — the paper's core serving story.

`--arch` selects any engine-served architecture: every family routes
through the same paged scheduling path via its cache descriptor — GQA
K/V blocks (qwen3-8b, the default), MLA latent blocks
(deepseek-v3-671b), hybrid shared-attn blocks + slot-resident SSM state
(zamba2-2.7b), pure SSM (mamba2-2.7b), or sliding-window GQA
(gemma3-1b: local-layer blocks are window-slide reclaimed
mid-generation while global-layer blocks stay pinned).

Run: PYTHONPATH=src python examples/serve_dual_precision.py \
         [--arch deepseek-v3-671b]
"""
import argparse

import numpy as np
import jax

from repro.configs import ARCHS
from repro.core.policy import DualPrecisionController, SLOConfig
from repro.models import model as M
from repro.models.convert import to_serving, serving_memory_bytes
from repro.serving.engine import Engine, Request

ap = argparse.ArgumentParser(
    formatter_class=argparse.RawDescriptionHelpFormatter,
    epilog="""\
one-dispatch engine steps
  Every engine iteration costs O(1) jitted dispatches however many
  sequences are prefilling or decoding: all planned prompt chunks fuse
  into ONE batched ragged paged_step (per-row q_offset/kv_len/
  logit_position carry the raggedness), block tables are DEVICE-resident
  (incremental jitted scatters on allocate/slide/COW instead of a full
  re-upload per step), and greedy sampling is fused into the step so
  decode pulls (B,) int32 token ids — not (B, vocab) logits — with one
  host sync at the end of the step. `benchmarks/bench_kernel_overhead.py`
  reports this as the engine_dispatch/* rows (prefill_dispatches_per_step
  == 1, table_h2d_bytes_per_decode_step << full table), consolidated
  into BENCH_results.json by `python -m benchmarks.run`.

--attn-backend selection
  ref     pure-jnp block-table gather attention (default; every family)
  pallas  planar decode attention runs in the block-table
          scalar-prefetch Pallas kernel (kernels/
          planar_decode_attention.py): fp16 mode rejoins the NestedKV
          byte planes in-kernel, fp8 mode DMAs ONLY the hi planes, and
          gemma3 sliding windows ride a traced per-layer window operand.
          Requires --kv-planar (GQA archs); anything the kernel cannot
          serve (prefill chunks, MLA/hybrid, f16 caches) falls back to
          the ref gather path. Interpret-mode (slow, exact) off-TPU.
""")
ap.add_argument("--arch", default="qwen3-8b", choices=sorted(ARCHS),
                help="architecture (reduced variant); any decoder-only "
                     "family serves through the paged engine")
ap.add_argument("--attn-backend", default="ref", choices=["ref", "pallas"],
                help="paged decode attention backend (see epilog); "
                     "pallas requires --kv-planar")
ap.add_argument("--kv-planar", action="store_true",
                help="store GQA KV as NestedKV byte planes (fp8 decode "
                     "reads half the cache bytes)")
args = ap.parse_args()
if args.attn_backend == "pallas" and not args.kv_planar:
    ap.error("--attn-backend pallas serves the byte-planar NestedKV "
             "cache; pass --kv-planar")

cfg = ARCHS[args.arch].reduced()
params = M.init_params(jax.random.PRNGKey(0), cfg)
sparams = to_serving(params)
mem = serving_memory_bytes(sparams)
desc = M.cache_descriptor(cfg)
print(f"model: {cfg.arch_id}, serving bytes {mem['total_bytes']/2**20:.1f} MiB")
print(f"cache descriptor: {desc.kind}, {desc.bytes_per_token} paged B/token, "
      f"{desc.bytes_per_slot} slot-resident B/seq")

# a controller calibrated so a full batch trips the SLO guard
ctrl = DualPrecisionController(SLOConfig(tpot_ms=33.3, hysteresis_steps=3),
                               fp16_ms_per_token=0.8, fp8_ms_per_token=0.4,
                               fixed_overhead_ms=2.0)
eng = Engine(cfg, sparams, n_slots=8, capacity=128, controller=ctrl,
             attn_backend=args.attn_backend, kv_planar=args.kv_planar)

rng = np.random.RandomState(1)
# every request opens with the same system prompt — on prefix-cacheable
# descriptors (gqa/mla) the COW prefix cache shares those KV blocks
# across the whole burst (one prefill, N readers); recurrent descriptors
# recompute them (slot-resident state cannot be shared)
system_prompt = list(rng.randint(1, 500, 32))
# light phase: 3 requests; burst: 12 at once; light again
for i in range(3):
    eng.submit(Request(f"light{i}",
                       system_prompt + list(rng.randint(1, 500, 12)),
                       max_new=6))
eng.run(max_iters=40)
for i in range(12):
    eng.submit(Request(f"burst{i}",
                       system_prompt + list(rng.randint(1, 500, 48)),
                       max_new=8))
eng.run(max_iters=400)

hist = ctrl.history
print(f"iterations: {len(hist)}, fp16 fraction: {ctrl.fp16_time_fraction():.2f}")
print("mode trace:", "".join("H" if m == "fp16" else "8" for m in hist))
assert "fp8" in hist and "fp16" in hist, "controller must use both modes"
ps = eng.prefix_cache_stats()
print(f"prefix cache: hit rate {ps['hit_rate']:.2f}, "
      f"blocks saved {ps['blocks_saved']}, cow forks {ps['cow_forks']}")
windowed = any(g.window for g in desc.groups)
if desc.prefix_cacheable and not windowed:
    assert ps["blocks_saved"] > 0, "shared system prompt never hit the cache"
if windowed:
    # sliding-window archs: once a holder decodes past the window, the
    # shared prefix's local-layer lookback blocks are slide-freed (and
    # evicted from the index — matching them would be illegal), so the
    # reuse story here is mid-generation block reclamation instead
    print(f"sliding window: {eng.stats['window_reclaimed_blocks']} "
          f"local-layer blocks reclaimed mid-generation")
    assert eng.stats["window_reclaimed_blocks"] > 0, \
        "long decode never slid a local block"
st = eng.stats
steps = max(eng.iteration, 1)
print(f"dispatch accounting over {steps} steps: "
      f"{st['prefill_dispatches']/steps:.2f} prefill + "
      f"{st['decode_dispatches']/steps:.2f} decode + "
      f"{st['aux_dispatches']/steps:.2f} aux dispatches/step, "
      f"{(st['h2d_bytes'] + eng.blocks.table_h2d_bytes)/steps:.0f} "
      f"h2d B/step")
print("finished requests:", len(eng.finished))
