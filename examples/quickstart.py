"""Quickstart: the NestedFP format + dual-precision linear in 20 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import NestedTensor, nested_linear, NestedLinearParams

# 1. any f16 weight with |w| <= 1.75 splits into two uint8 tensors
w = jnp.asarray(np.random.RandomState(0).uniform(-1.5, 1.5, (512, 256))
                .astype(np.float16))
nt = NestedTensor.from_f16(w)
print(f"storage: upper {nt.upper.nbytes}B + lower {nt.lower.nbytes}B "
      f"== f16 {w.nbytes}B  (zero overhead)")

# 2. FP16 read is BIT-EXACT (paper's lossless reconstruction)
assert np.array_equal(np.asarray(nt.read_f16()).view(np.uint16),
                      np.asarray(w).view(np.uint16))
print("fp16 reconstruction: bit-exact ✓")

# 3. FP8 read is the upper byte — a valid e4m3 tensor at scale 2^-8
w8, scale = nt.read_fp8()
err = np.abs(np.asarray(w8, np.float32) * float(scale) - np.asarray(w, np.float32))
print(f"fp8 view: max |err| = {err.max():.4f} (e4m3 grid)")

# 4. one linear layer, two precisions, same bytes
x = jnp.asarray(np.random.randn(4, 512).astype(np.float16))
params = NestedLinearParams(weight=nt, bias=None)
y16 = nested_linear(params, x, mode="fp16", out_dtype=jnp.float32)
y8 = nested_linear(params, x, mode="fp8", out_dtype=jnp.float32)
cos = float(jnp.sum(y16*y8) / (jnp.linalg.norm(y16)*jnp.linalg.norm(y8)))
print(f"fp16 vs fp8 output cosine: {cos:.5f}")
