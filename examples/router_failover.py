"""Fault-tolerant multi-replica serving demo: a shared-prefix burst
through a 3-replica `Router` while a seeded `FaultPlan` kills one
replica mid-generation and revives it later.

What to watch for in the output:

* the kill drains the dead replica's in-flight requests and replays
  them on the survivors — partly from the survivors' own warm prefix
  KV (restored tokens), partly recomputed — and the final outputs are
  BIT-IDENTICAL to a no-fault run (greedy generation is batch-invariant
  and replay re-establishes prompt + already-emitted tokens);
* the `DegradePolicy` pins survivors to FP8 while the fleet runs
  short-handed (same nested weight buffers, per-iteration switch, so
  the capacity response is free) and re-probes FP16 only after a
  hysteresis dwell once the replica returns — FP8 rounding changes
  tokens, so the bit-exactness run keeps `force_fp8=False` and the
  degradation run demonstrates the mode response instead;
* `Router.stats()["lost"]` stays 0: every submitted request is
  exactly-once completed, shed, or in flight.

Run: PYTHONPATH=src python examples/router_failover.py [--replicas 3]
"""
import argparse

import numpy as np
import jax

from repro.configs import ARCHS
from repro.core.policy import DegradePolicy
from repro.models import model as M
from repro.models.convert import to_serving
from repro.serving.engine import Request
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.router import Router, StepCostModel, VirtualClock

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
ap.add_argument("--replicas", type=int, default=3)
ap.add_argument("--kill-step", type=int, default=5,
                help="router step at which replica 0 is killed")
args = ap.parse_args()

cfg = ARCHS[args.arch].reduced()
sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
engine_kwargs = dict(n_slots=4, capacity=128, forced_mode="fp16",
                     block_size=16, n_blocks=24, chunk_tokens=64)

rng = np.random.RandomState(0)
system_prompt = list(rng.randint(1, 500, 32))


def burst(n=8, max_new=12):
    return [Request(f"r{i}", system_prompt + list(
        np.random.RandomState(13 * i + 1).randint(1, 500, 8)), max_new)
        for i in range(n)]


def serve(plan, force_fp8):
    vc = VirtualClock()
    router = Router.build(
        cfg, sparams, args.replicas,
        engine_kwargs=dict(engine_kwargs, clock=vc),
        plan=plan, clock=vc, cost_model=StepCostModel(),
        policy=DegradePolicy(force_fp8=force_fp8, shed_budget_tokens=2048,
                             restore_scale=0.5, hysteresis_steps=6),
        affinity_blocks=1, balance_slack_tokens=64)
    for req in burst():
        router.submit(req)
    router.run()
    return ({r.request_id: tuple(r.output) for r in router.finished},
            router.stats(), router)


def report(st):
    print(f"  completed {st['completed']}/{st['submitted']} in "
          f"{st['steps']} steps, lost={st['lost']}, shed={st['shed']}")
    print(f"  replicas: {st['replicas']}")
    print(f"  failover: {st['failover_requests']} requests re-homed, "
          f"{st['failover_restored_tokens']} tokens restored from warm "
          f"KV, {st['failover_recomputed_tokens']} recomputed")


plan = FaultPlan([FaultEvent(args.kill_step, 0, "kill"),
                  FaultEvent(args.kill_step + 8, 0, "revive")])

print(f"model: {cfg.arch_id}, replicas: {args.replicas}")
print("— no-fault reference run —")
ref, ref_st, _ = serve(plan=None, force_fp8=False)
print(f"  completed {ref_st['completed']}/{ref_st['submitted']} in "
      f"{ref_st['steps']} steps")

print(f"— chaos run (fp16 failover): kill replica 0 @ step "
      f"{args.kill_step}, revive @ step {args.kill_step + 8} —")
out, st, _ = serve(plan, force_fp8=False)
report(st)
assert st["lost"] == 0, "a request was lost"
assert st["kills"] == 1 and st["failover_requests"] > 0
assert out == ref, "failover continuation diverged from no-fault run"
print("  outputs BIT-IDENTICAL to the no-fault run; zero lost")

print("— chaos run (FP8 degradation): same plan, force_fp8=True —")
out8, st8, router = serve(plan, force_fp8=True)
report(st8)
print(f"  degrade: {st8['degrade_fp8_steps']} survivor-steps pinned "
      f"FP8, per-replica dwell {st8['fp8_dwell']}")
assert st8["lost"] == 0 and st8["degrade_fp8_steps"] > 0
# idle the fleet past the hysteresis dwell: FP16 is re-probed only
# after the revived replica has proven itself for a full dwell
for _ in range(12):
    router.step()
modes = {r.rid: r.engine.forced_mode for r in router.replicas}
print(f"  after revive + hysteresis dwell, forced modes: {modes}")
assert all(m == "fp16" for m in modes.values()), modes
print("fleet degraded to FP8 under the kill, re-probed FP16 after "
      "recovery; zero lost in every run")
