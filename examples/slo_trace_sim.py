"""Reproduce paper Fig. 1b: p90 TPOT + SLO-violation seconds for FP16-only,
FP8-only, and NestedFP dual-precision serving on a bursty Azure-like trace
(cost model calibrated to Llama-3.1-8B on one H100-class budget).

Run: PYTHONPATH=src python examples/slo_trace_sim.py
"""
from repro.serving import simulate, trace

reqs = trace.azure_like(duration_s=60, mean_rate=5.05, seed=7,
                        prompt_len=256, max_new=512)
print("trace:", trace.rate_stats(reqs, 60))

# Llama-3.1-8B-ish: 8B params, H100 bw/compute budget scaled to our cost model
cost = simulate.CostModel(fixed_ms=2.0, weight_read_ms_fp16=16.0,
                          weight_read_ms_fp8=8.0, kv_ms_per_ktoken=0.002,
                          compute_ms_per_token_fp16=0.055,
                          compute_ms_per_token_fp8=0.0275)
print(f"{'policy':8s} {'p90 TPOT':>9s} {'p90 TTFT':>9s} {'SLO-viol s':>10s} "
      f"{'%fp16':>6s} {'finished':>8s}")
for pol in ("fp16", "fp8", "dual"):
    r = simulate.simulate(reqs, cost, policy=pol)
    print(f"{pol:8s} {r.p90_tpot_ms:9.1f} {r.p90_ttft_ms:9.1f} "
          f"{r.slo_violation_s:10.1f} {r.fp16_fraction*100:6.1f} "
          f"{r.n_finished:8d}")
