"""Substrate tests: optimizer, data pipeline, checkpointing, training loop."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# optimizer/pipeline/checkpoint/training-loop integration — slow lane
pytestmark = pytest.mark.slow

from repro.checkpoint import io as ckpt_io
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, SyntheticLM, microbatch_split
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=100)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw.init_state(cfg, params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_clipping_and_schedule(self):
        cfg = adamw.AdamWConfig(lr=1e-2, clip_norm=1.0, warmup_steps=10,
                                total_steps=100)
        params = {"w": jnp.ones((4,))}
        state = adamw.init_state(cfg, params)
        _, state, m = adamw.apply_updates(cfg, params,
                                          {"w": jnp.full((4,), 100.0)}, state)
        assert float(m["grad_norm"]) > 100
        assert float(m["lr"]) == pytest.approx(1e-3, rel=0.05)  # warmup 1/10

    def test_low_mem_moments_dtype(self):
        cfg = adamw.AdamWConfig(low_mem=True)
        state = adamw.init_state(cfg, {"w": jnp.ones((4, 4))})
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_no_decay_on_vectors(self):
        cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0)
        params = {"norm": jnp.ones((8,)), "w": jnp.ones((8, 8))}
        state = adamw.init_state(cfg, params)
        p2, _, _ = adamw.apply_updates(
            cfg, params, jax.tree.map(jnp.zeros_like, params), state)
        np.testing.assert_allclose(np.asarray(p2["norm"]), 1.0)
        assert float(p2["w"][0, 0]) < 1.0


class TestData:
    def test_deterministic(self):
        cfg = ARCHS["qwen1.5-0.5b"].reduced()
        d = DataConfig(seq_len=64, global_batch=4, seed=7)
        a = next(SyntheticLM(cfg, d).batches(1))
        b = next(SyntheticLM(cfg, d).batches(1))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_learnable_structure(self):
        """Copy motif: token at i repeats token at i-24 often."""
        cfg = ARCHS["qwen1.5-0.5b"].reduced()
        d = DataConfig(seq_len=512, global_batch=2, seed=0)
        toks = next(SyntheticLM(cfg, d).batches(1))["tokens"]
        t = toks[0]
        rep = np.mean(t[24:] == t[:-24])
        assert rep > 0.05

    def test_vlm_and_encdec_extras(self):
        for arch in ("phi-3-vision-4.2b", "seamless-m4t-large-v2"):
            cfg = ARCHS[arch].reduced()
            b = next(SyntheticLM(cfg, DataConfig(64, 2)).batches(1))
            assert "patch_embeds" in b or "frames" in b

    def test_microbatch_split(self):
        b = {"tokens": np.arange(8 * 5).reshape(8, 5)}
        mb = microbatch_split(b, 4)
        assert mb["tokens"].shape == (4, 2, 5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.float16)}}
        ckpt_io.save(str(tmp_path / "ck"), tree, step=7)
        back, step = ckpt_io.restore(str(tmp_path / "ck"), tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_shape_mismatch_raises(self, tmp_path):
        tree = {"a": jnp.ones((4,))}
        ckpt_io.save(str(tmp_path / "ck"), tree)
        with pytest.raises(ValueError):
            ckpt_io.restore(str(tmp_path / "ck"), {"a": jnp.ones((5,))})

    def test_nested_params_roundtrip(self, tmp_path):
        from repro.models.convert import to_serving
        cfg = ARCHS["qwen1.5-0.5b"].reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        sp = to_serving(params)
        ckpt_io.save(str(tmp_path / "ck"), sp)
        back, _ = ckpt_io.restore(str(tmp_path / "ck"), sp)
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestTrainingLoop:
    def test_loss_decreases_tiny_model(self):
        cfg = ARCHS["qwen1.5-0.5b"].reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=2)
        opt = adamw.init_state(opt_cfg, params)
        step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        data = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
        losses = []
        for batch in data.batches(30):
            b = microbatch_split({k: jnp.asarray(v) for k, v in batch.items()}, 2)
            params, opt, metrics = step(params, opt, b)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.2, losses[::6]

    def test_microbatched_matches_unmicrobatched_grads(self):
        """scan-accumulated grads == full-batch grads (linearity check)."""
        from repro.models.layers import Runtime
        cfg = ARCHS["qwen1.5-0.5b"].reduced()
        rt = Runtime(mode="train", dtype=jnp.float32)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        data = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
        batch = {k: jnp.asarray(v) for k, v in next(data.batches(1)).items()}

        def loss_fn(p, b):
            return M.train_loss(rt, p, cfg, b)[0]

        g_full = jax.grad(loss_fn)(params, batch)
        g_acc = jax.tree.map(jnp.zeros_like, params)
        for i in range(4):
            mb = {k: v[i:i + 1] for k, v in batch.items()}
            g = jax.grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, x: a + x / 4, g_acc, g)
        flat_a = np.concatenate([np.asarray(x, np.float64).ravel()
                                 for x in jax.tree_util.tree_leaves(g_full)])
        flat_b = np.concatenate([np.asarray(x, np.float64).ravel()
                                 for x in jax.tree_util.tree_leaves(g_acc)])
        rel = np.linalg.norm(flat_a - flat_b) / np.linalg.norm(flat_a)
        assert rel < 1e-4, rel
