"""Speculative decoding on the paged engine: n-gram draft proposal,
adaptive draft length, BlockManager truncate rollback, and the engine
bit-exactness contract — greedy outputs identical with speculation on or
off across precision modes, prefix caching, preemption, and gemma3
window reclaim (drafts only decide how many tokens one dispatch
confirms, never which tokens)."""

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core.policy import (AdaptiveKController, SpeculationConfig,
                               StepObservation)
from repro.models import model as M
from repro.models.convert import to_serving
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import TRASH_BLOCK, BlockManager
from repro.serving.speculate import NgramProposer


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


@pytest.fixture(scope="module")
def tiny_swa():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


# =============================================================================
# draft proposer
# =============================================================================

class TestNgramProposer:
    def test_matches_most_recent_occurrence(self):
        p = NgramProposer(SpeculationConfig(ngram_max=2, ngram_min=1))
        # suffix [1, 2] occurs twice; the later one is followed by 7
        hist = [1, 2, 5, 9, 1, 2, 7, 8, 1, 2]
        assert p.propose(hist, 2) == [7, 8]

    def test_longest_ngram_wins(self):
        p = NgramProposer(SpeculationConfig(ngram_max=3, ngram_min=1))
        # 1-gram suffix [4] recurs at index 1 (followed by 9), but the
        # 3-gram [2, 3, 4] recurs earlier followed by 5 — longest wins
        hist = [2, 3, 4, 5, 4, 9, 2, 3, 4]
        assert p.propose(hist, 1) == [5]

    def test_no_match_returns_empty(self):
        p = NgramProposer()
        assert p.propose([1, 2, 3, 4, 5], 4) == []
        assert p.propose([7], 4) == []       # too short to match itself
        assert p.propose([], 4) == []

    def test_k_clamps_the_draft(self):
        p = NgramProposer(SpeculationConfig(ngram_max=1, ngram_min=1))
        hist = [5, 1, 2, 3, 4, 5]
        assert p.propose(hist, 3) == [1, 2, 3]
        assert p.propose(hist, 99) == [1, 2, 3, 4, 5]
        assert p.propose(hist, 0) == []

    def test_pure_repetition_drafts_the_loop(self):
        p = NgramProposer(SpeculationConfig(ngram_min=1))
        assert p.propose([6, 6, 6, 6], 3) == [6, 6, 6]


# =============================================================================
# adaptive draft length
# =============================================================================

def _obs(drafted, accepted):
    return StepObservation(batch_tokens=1, queue_depth=0,
                           measured_step_ms=None, spec_drafted=drafted,
                           spec_accepted=accepted)


class TestAdaptiveK:
    def test_grows_on_high_acceptance_to_ceiling(self):
        c = AdaptiveKController(SpeculationConfig(k_init=2, k_max=4))
        for _ in range(20):
            k = c.decide(_obs(4, 4))
        assert k == 4 and max(c.history) == 4

    def test_shrinks_on_rejection_but_floors_at_k_min(self):
        c = AdaptiveKController(SpeculationConfig(k_init=4, k_min=1))
        for _ in range(20):
            k = c.decide(_obs(4, 0))
        # the floor keeps the acceptance signal alive: K=0 would draft
        # nothing and the controller could never observe a regime change
        assert k == 1

    def test_no_adaptation_below_min_drafted(self):
        c = AdaptiveKController(
            SpeculationConfig(k_init=3, adapt_min_drafted=50))
        for _ in range(5):
            assert c.decide(_obs(4, 0)) == 3

    def test_draftless_steps_leave_the_window_alone(self):
        c = AdaptiveKController(SpeculationConfig(k_init=2))
        for _ in range(10):
            c.decide(_obs(4, 4))
        k = c.k
        for _ in range(10):
            c.decide(_obs(0, 0))             # no drafts: no evidence
        assert c.k == k
        assert c.acceptance_rate() == 1.0


# =============================================================================
# truncate rollback (BlockManager unit)
# =============================================================================

class TestTruncate:
    def test_drops_exclusive_blocks_back_to_free_list(self):
        bm = BlockManager(2, 4, 8, 8, prefix_cache=False)
        a = bm.try_allocate("a", 4, 12)
        assert bm.ensure(a, 14)              # 4 blocks
        bm.set_length(a, 9)
        free0 = bm.n_free_blocks()
        assert bm.truncate(a, 6) == 2        # blocks 2,3 dropped
        assert bm.n_free_blocks() == free0 + 2
        assert bm.seqs[a].length == 6
        tab = bm.table(a)
        assert (tab[2:] == TRASH_BLOCK).all() and (tab[:2] != TRASH_BLOCK).all()
        bm.check_invariants()

    def test_truncate_above_coverage_is_a_noop(self):
        bm = BlockManager(2, 4, 8, 8)
        a = bm.try_allocate("a", 4, 4)
        assert bm.ensure(a, 5)
        bm.set_length(a, 5)
        assert bm.truncate(a, 100) == 0
        assert bm.seqs[a].length == 5
        bm.check_invariants()

    def test_shared_block_survives_for_other_holder(self):
        toks = list(range(12))
        bm = BlockManager(2, 4, 8, 8, prefix_cache=True)
        a = bm.try_allocate("a", 12, 0, bm.prefix_admit_discount(toks))
        assert bm.ensure(a, 12)
        bm.commit(a, 12, toks)               # 3 registered full blocks
        b = bm.try_allocate("b", 12, 0, bm.prefix_admit_discount(toks))
        assert bm.attach_prefix(b, toks) == 12
        shared = list(bm.seqs[b].groups[0].blocks)
        assert bm.truncate(b, 4) == 2        # b lets go of 2 shared blocks
        # a still owns them, bytes untouched, still prefix-matchable
        assert bm.seqs[a].groups[0].blocks == shared
        assert bm.lookup_prefix(toks) == 12
        bm.check_invariants()

    def test_registered_exclusive_block_parks_in_lru(self):
        toks = list(range(8))
        bm = BlockManager(2, 4, 8, 8, prefix_cache=True)
        a = bm.try_allocate("a", 8, 0, bm.prefix_admit_discount(toks))
        assert bm.ensure(a, 8)
        bm.commit(a, 8, toks)
        cached0 = bm.n_cached_blocks()
        bm.truncate(a, 4)                    # drop a committed full block
        assert bm.n_cached_blocks() == cached0 + 1
        # its content is intact, so a later admission still attaches it
        assert bm.lookup_prefix(toks) == 8
        bm.check_invariants()

    def test_partial_cut_evicts_tail_from_index(self):
        toks = list(range(8))
        bm = BlockManager(2, 4, 8, 8, prefix_cache=True)
        a = bm.try_allocate("a", 8, 0, bm.prefix_admit_discount(toks))
        assert bm.ensure(a, 8)
        bm.commit(a, 8, toks)
        assert bm.lookup_prefix(toks) == 8
        ev0 = bm.prefix_stats["evictions"]
        bm.truncate(a, 6)                    # second block now half-valid
        # future writes at positions 6,7 would diverge from the
        # registered content — the entry must be gone before that
        assert bm.prefix_stats["evictions"] == ev0 + 1
        assert bm.lookup_prefix(toks) == 4
        bm.check_invariants()

    def test_slid_holes_stay_holes(self):
        # windowed local group (gemma3 descriptor): slide, then truncate
        # — the leading holes must never be resurrected or released twice
        bm = BlockManager(2, 4, 12, 8, prefix_cache=False,
                          group_windows=(None, 5))
        a = bm.try_allocate("a", 4, 24)
        assert bm.ensure(a, 26)
        bm.set_length(a, 25)
        bm.slide_window(a)
        g = bm.seqs[a].groups[1]
        assert g.slid > 0
        holes = list(g.blocks[:g.slid])
        assert all(b == TRASH_BLOCK for b in holes)
        bm.truncate(a, 9)
        assert g.blocks[:min(g.slid, len(g.blocks))] == \
            holes[:min(g.slid, len(g.blocks))]
        bm.check_invariants()

    def test_device_mirror_tracks_truncate(self):
        bm = BlockManager(2, 4, 8, 8)
        a = bm.try_allocate("a", 4, 12)
        assert bm.ensure(a, 14)
        bm.set_length(a, 13)
        np.testing.assert_array_equal(np.asarray(bm.device_tables()),
                                      bm.group_tables())
        bm.truncate(a, 3)
        # the dirty-scatter overlay must carry the trashed entries too
        np.testing.assert_array_equal(np.asarray(bm.device_tables()),
                                      bm.group_tables())
        bm.check_invariants()


# =============================================================================
# engine end-to-end
# =============================================================================

REP = [5, 6, 7, 8] * 6                       # repetitive: drafts accept
MIX = [list(range(3, 11)), list(range(40, 48)), REP]
SPEC = SpeculationConfig(ngram_min=1)


def _outputs(cfg, sparams, prompts, *, speculate=None, max_new=8, **kw):
    eng = Engine(cfg, sparams, n_slots=4, capacity=96, **kw,
                 speculate=speculate)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", list(p), max_new=max_new))
    fin = {r.request_id: r.output for r in eng.run()}
    return [fin[f"r{i}"] for i in range(len(prompts))], eng


@pytest.mark.slow
class TestSpeculativeEngine:
    @pytest.mark.parametrize("mode", ["fp16", "fp8"])
    def test_bit_exact_on_off(self, tiny, mode):
        cfg, sparams = tiny
        off, _ = _outputs(cfg, sparams, MIX, forced_mode=mode)
        on, eng = _outputs(cfg, sparams, MIX, forced_mode=mode,
                           speculate=SPEC)
        assert on == off
        assert eng.spec_stats()["accepted"] > 0, \
            "repetitive prompt never accepted a draft — vacuous parity"
        eng.blocks.check_invariants()

    def test_bit_exact_with_prefix_cache_sharing(self, tiny):
        """Seed the cache with one full run, then serve two requests whose
        prompts share its prefix — speculation must not disturb the
        shared blocks (accepted runs COW before writing)."""
        cfg, sparams = tiny

        def serve(spec):
            eng = Engine(cfg, sparams, n_slots=4, capacity=96,
                         forced_mode="fp16", prefix_cache=True,
                         block_size=4, speculate=spec)
            eng.submit(Request("seed", list(REP), max_new=8))
            eng.run()
            for i, p in enumerate([REP, list(REP) + [9, 9]]):
                eng.submit(Request(f"r{i}", list(p), max_new=8))
            fin = {r.request_id: r.output for r in eng.run()}
            return [fin["seed"], fin["r0"], fin["r1"]], eng

        off, e0 = serve(None)
        on, e1 = serve(SPEC)
        assert on == off
        assert e1.prefix_cache_stats()["hit_rate"] > 0, \
            e1.prefix_cache_stats()
        e1.blocks.check_invariants()

    def test_bit_exact_under_preemption(self, tiny):
        cfg, sparams = tiny
        kw = dict(forced_mode="fp16", block_size=4, n_blocks=14,
                  max_new=10)
        off, e0 = _outputs(cfg, sparams, MIX, **kw)
        on, e1 = _outputs(cfg, sparams, MIX, speculate=SPEC, **kw)
        assert on == off
        assert e1.stats["preemptions"] > 0 or e0.stats["preemptions"] > 0, \
            "pool never tight enough to preempt — vacuous"
        e1.blocks.check_invariants()

    def test_bit_exact_gemma3_window_reclaim(self, tiny_swa):
        cfg, sparams = tiny_swa
        prompts = [[3, 4, 5] * 9, [11, 12] * 12]     # > window 19
        kw = dict(forced_mode="fp16", block_size=4, max_new=10)
        off, e0 = _outputs(cfg, sparams, prompts, **kw)
        on, e1 = _outputs(cfg, sparams, prompts, speculate=SPEC, **kw)
        assert on == off
        assert e1.stats["window_reclaimed_blocks"] > 0, \
            "local-layer window never slid — vacuous"
        e1.blocks.check_invariants()

    def test_acceptance_reduces_dispatches(self, tiny):
        cfg, sparams = tiny
        off, e0 = _outputs(cfg, sparams, [REP], forced_mode="fp16",
                           max_new=12)
        on, e1 = _outputs(cfg, sparams, [REP], forced_mode="fp16",
                          max_new=12, speculate=SPEC)
        assert on == off
        ss = e1.spec_stats()
        assert ss["spec_dispatches"] > 0
        assert ss["tokens_accepted_per_dispatch"] > 1.0
        assert e1.stats["decode_dispatches"] < e0.stats["decode_dispatches"]
        # draft verification rides INSIDE the decode dispatch: no extra
        # prefill or aux work appears
        assert e1.stats["prefill_dispatches"] == e0.stats["prefill_dispatches"]

    def test_eos_stops_accepted_run_mid_run(self, tiny):
        cfg, sparams = tiny
        full, _ = _outputs(cfg, sparams, [REP], forced_mode="fp16",
                           max_new=12)
        stop = full[0][3]                    # mid-stream token as EOS
        want = full[0][:full[0].index(stop) + 1]
        for spec in (None, SPEC):
            eng = Engine(cfg, sparams, n_slots=4, capacity=96,
                         forced_mode="fp16", speculate=spec)
            eng.submit(Request("r", list(REP), max_new=12,
                               stop_tokens=(stop,)))
            out = eng.run()[0].output
            assert out == want, (spec, out, want)
            eng.blocks.check_invariants()

    def test_eos_on_first_generated_token(self, tiny):
        cfg, sparams = tiny
        full, _ = _outputs(cfg, sparams, [REP], forced_mode="fp16",
                           max_new=12)
        for spec in (None, SPEC):
            eng = Engine(cfg, sparams, n_slots=4, capacity=96,
                         forced_mode="fp16", speculate=spec)
            eng.submit(Request("r", list(REP), max_new=12,
                               stop_tokens=(full[0][0],)))
            fin = eng.run()
            # previously a first-token EOS decoded on to max_new: the
            # pending patch never fed the stop-token check
            assert fin[0].output == [full[0][0]], (spec, fin[0].output)
            eng.blocks.check_invariants()

    def test_recurrent_family_rejects_speculation(self):
        cfg = ARCHS["zamba2-2.7b"].reduced()
        params = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
        with pytest.raises(ValueError, match="roll"):
            Engine(cfg, params, n_slots=2, capacity=64, speculate=True)

    def test_spec_stats_guard_zero_traffic(self, tiny):
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=64, speculate=True)
        ss = eng.spec_stats()                # no requests ever served
        assert ss["acceptance_rate"] == 0.0
        assert ss["tokens_accepted_per_dispatch"] == 0.0
