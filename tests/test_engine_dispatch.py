"""One-dispatch engine steps: batched ragged prefill fusion (one jitted
dispatch per step regardless of concurrent prefills, bit-exact vs solo
serving), device-resident block tables (incremental scatter flushes
mirror the host tables exactly), fused on-device greedy sampling
(`paged_step` returns token ids; `return_logits=True` is the escape
hatch), and the wired `attn_backend="pallas"` paged decode path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import TRASH_BLOCK, BlockManager


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


RNG = np.random.RandomState(11)
PROMPTS = [list(RNG.randint(1, 200, n)) for n in (13, 29, 7, 21)]


class TestFusedPrefill:
    def test_one_dispatch_regardless_of_concurrent_prefills(self, tiny):
        """The acceptance criterion: a step that plans N prompt chunks
        costs ONE jitted prefill dispatch, for any N."""
        cfg, sparams = tiny
        for n in (1, 2, 4):
            eng = Engine(cfg, sparams, n_slots=8, capacity=64,
                         forced_mode="fp16", chunk_tokens=512,
                         prefix_cache=False)
            for i in range(n):
                eng.submit(Request(f"r{i}", PROMPTS[i], max_new=2))
            eng.step()
            assert eng.stats["chunks"] == n, eng.stats
            assert eng.stats["prefill_dispatches"] == 1, \
                f"{n} concurrent prefills took " \
                f"{eng.stats['prefill_dispatches']} dispatches"
            assert eng.stats["decode_dispatches"] == 1

    def test_fused_batch_matches_solo_serving_bit_exact(self, tiny):
        """Concurrently-fused ragged prefill rows must produce the same
        greedy outputs as serving each request alone (pad rows and row
        bucketing cannot perturb real rows' arithmetic)."""
        cfg, sparams = tiny

        def serve(reqs, **kw):
            eng = Engine(cfg, sparams, n_slots=8, capacity=64,
                         forced_mode="fp16", chunk_tokens=512,
                         prefix_cache=False, **kw)
            for i, p in reqs:
                eng.submit(Request(f"r{i}", p, max_new=4))
            return {r.request_id: r.output for r in eng.run()}

        fused = serve(list(enumerate(PROMPTS)))
        assert fused == {
            f"r{i}": serve([(i, p)])[f"r{i}"]
            for i, p in enumerate(PROMPTS)}

    def test_chunked_budget_splits_still_fuse(self, tiny):
        """A small chunk budget splits prompts across steps; each step
        still fuses its planned chunks into one dispatch."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16", chunk_tokens=16,
                     prefix_cache=False)
        for i, p in enumerate(PROMPTS[:3]):
            eng.submit(Request(f"r{i}", p, max_new=2))
        while eng.prefilling or eng.queue:
            before = eng.stats["prefill_dispatches"]
            eng.step()
            assert eng.stats["prefill_dispatches"] - before <= 1
        eng.run()
        assert len(eng.finished) == 3


class TestDeviceTables:
    def test_mirror_tracks_host_tables_through_lifecycle(self):
        bm = BlockManager(4, 4, 16, 4, prefix_cache=True)
        a = bm.try_allocate("a", 8, 4)
        bm.ensure(a, 8)
        assert (np.asarray(bm.device_tables()) == bm.group_tables()).all()
        toks = list(range(8))
        bm.commit(a, 8, toks)
        b = bm.try_allocate("b", 8, 4)
        bm.attach_prefix(b, toks)           # shares a's blocks
        bm.ensure(b, 8)
        assert (np.asarray(bm.device_tables()) == bm.group_tables()).all()
        pairs = bm.cow_for_write(b, 4, 8)   # fork the shared tail
        assert pairs
        assert (np.asarray(bm.device_tables()) == bm.group_tables()).all()
        bm.release(a)
        bm.release(b)
        assert (np.asarray(bm.device_tables()) == bm.group_tables()).all()
        assert (np.asarray(bm.device_tables()) == TRASH_BLOCK).all()
        bm.check_invariants()

    def test_windowed_slide_updates_mirror(self):
        bm = BlockManager(2, 4, 16, 8, group_windows=(None, 5))
        a = bm.try_allocate("a", 4, 24)
        bm.device_tables()                  # materialize the mirror
        for n in range(4, 29, 4):
            assert bm.ensure(a, n)
            bm.set_length(a, n)
        bm.slide_window(a)
        assert bm.window_freed_blocks > 0
        assert (np.asarray(bm.device_tables()) == bm.group_tables()).all()
        bm.check_invariants()

    def test_incremental_flush_is_small(self):
        """Steady-state flushes ship O(changed entries), not the full
        (G, n_slots, MB) array."""
        bm = BlockManager(16, 16, 256, 16)
        idx = bm.try_allocate("a", 16, 64)
        bm.ensure(idx, 16)
        bm.device_tables()                  # full upload happens once
        full = bm.group_tables().nbytes
        b0 = bm.table_h2d_bytes
        for n in range(32, 129, 16):        # one new block per flush
            bm.ensure(idx, n)
            bm.device_tables()
        per_flush = (bm.table_h2d_bytes - b0) / 7
        assert per_flush < full / 4, (per_flush, full)

    def test_engine_decode_steps_do_not_reupload_tables(self, tiny):
        """After prefill, pure decode inside a block uploads ZERO table
        bytes (nothing changed); crossing a block edge uploads one
        incremental flush."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16", prefix_cache=False)
        eng.submit(Request("r", list(range(5, 20)), max_new=20))
        eng.step()                          # 15-token prefill + 1 decode
        full = eng.blocks.group_tables().nbytes
        b0 = eng.blocks.table_h2d_bytes
        eng.step()                          # len 16 -> 17: new block
        grew = eng.blocks.table_h2d_bytes - b0
        assert 0 < grew < full
        b1 = eng.blocks.table_h2d_bytes
        for _ in range(3):                  # len 17..20: inside block 2
            eng.step()
        assert eng.blocks.table_h2d_bytes == b1


class TestFusedSampling:
    def test_paged_step_returns_argmax_ids(self, tiny):
        """Default return is on-device greedy ids; return_logits=True is
        the escape hatch and must agree with the ids."""
        cfg, sparams = tiny
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        bs = 16
        caches = M.init_paged_cache(cfg, n_total_blocks=5, block_size=bs)
        table = np.zeros((1, 4), np.int32)
        table[0, 0] = 1
        kw = dict(q_offset=jnp.asarray([0], jnp.int32),
                  kv_len=jnp.asarray([9], jnp.int32), block_size=bs,
                  logit_position=jnp.asarray([8], jnp.int32))
        toks = np.zeros((1, 16), np.int32)
        toks[0, :9] = range(7, 16)
        logits, _ = M.paged_step(rt, sparams, cfg, jnp.asarray(toks),
                                 caches, jnp.asarray(table),
                                 return_logits=True, **kw)
        ids, _ = M.paged_step(rt, sparams, cfg, jnp.asarray(toks), caches,
                              jnp.asarray(table), **kw)
        assert ids.dtype == jnp.int32 and ids.shape == (1,)
        assert int(ids[0]) == int(np.asarray(jnp.argmax(logits, -1))[0])

    def test_no_pending_placeholder_leaks(self, tiny):
        """Every output token is a real vocab id after run() — the
        end-of-step sync must patch all device-pending entries,
        including requests retired on their first token."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16")
        eng.submit(Request("one", list(range(3, 10)), max_new=1))
        eng.submit(Request("more", list(range(30, 50)), max_new=5))
        fin = {r.request_id: r.output for r in eng.run()}
        assert len(fin["one"]) == 1 and len(fin["more"]) == 5
        for out in fin.values():
            assert all(0 <= t < cfg.vocab_size for t in out), out


class TestPallasBackend:
    def test_paged_decode_matches_ref_gather(self, tiny):
        """attn_backend='pallas' decode logits vs the ref gather path on
        the SAME planar caches: the kernel's online softmax accumulates
        per block, so parity is tight-tolerance, not bitwise."""
        cfg, sparams = tiny
        bs = 16
        table = np.zeros((2, 4), np.int32)
        table[0, :2] = [1, 2]
        table[1, :2] = [3, 4]
        caches = M.init_paged_cache(cfg, n_total_blocks=9, block_size=bs,
                                    planar=True)
        rt_ref = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        # prefill both rows through the ref path (chunks never hit pallas)
        toks = np.zeros((2, 16), np.int32)
        toks[0, :13] = range(5, 18)
        toks[1, :9] = range(40, 49)
        _, caches = M.paged_step(
            rt_ref, sparams, cfg, jnp.asarray(toks), caches,
            jnp.asarray(table), q_offset=jnp.asarray([0, 0], jnp.int32),
            kv_len=jnp.asarray([13, 9], jnp.int32), block_size=bs,
            logit_position=jnp.asarray([12, 8], jnp.int32))
        dec = jnp.asarray([[3], [7]], np.int32)
        kw = dict(q_offset=jnp.asarray([13, 9], jnp.int32),
                  kv_len=jnp.asarray([14, 10], jnp.int32), block_size=bs,
                  return_logits=True)
        for mode in ("fp16", "fp8"):
            ref, _ = M.paged_step(
                Runtime(mode=mode, backend="ref", dtype=jnp.float32),
                sparams, cfg, dec, caches, jnp.asarray(table), **kw)
            got, _ = M.paged_step(
                Runtime(mode=mode, backend="ref", dtype=jnp.float32,
                        attn_backend="pallas"),
                sparams, cfg, dec, caches, jnp.asarray(table), **kw)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_windowed_decode_matches_ref_gather(self):
        """gemma3-style stack: the scanned per-layer window reaches the
        kernel as a traced operand — local layers must mask to the
        window, global layers must not, matching the ref gather path."""
        cfg = ARCHS["gemma3-1b"].reduced()
        sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
        assert cfg.sliding_window and cfg.sliding_window < 45
        bs = 16
        table = np.zeros((1, 4), np.int32)
        table[0, :3] = [1, 2, 3]
        caches = M.init_paged_cache(cfg, n_total_blocks=9, block_size=bs,
                                    planar=True)
        rt_ref = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        toks = np.zeros((1, 48), np.int32)   # prompt > 2x the window
        toks[0, :45] = range(5, 50)
        _, caches = M.paged_step(
            rt_ref, sparams, cfg, jnp.asarray(toks), caches,
            jnp.asarray(table), q_offset=jnp.asarray([0], jnp.int32),
            kv_len=jnp.asarray([45], jnp.int32), block_size=bs,
            logit_position=jnp.asarray([44], jnp.int32))
        dec = jnp.asarray([[9]], np.int32)
        kw = dict(q_offset=jnp.asarray([45], jnp.int32),
                  kv_len=jnp.asarray([46], jnp.int32), block_size=bs,
                  return_logits=True)
        ref, _ = M.paged_step(rt_ref, sparams, cfg, dec, caches,
                              jnp.asarray(table), **kw)
        got, _ = M.paged_step(
            Runtime(mode="fp16", backend="ref", dtype=jnp.float32,
                    attn_backend="pallas"),
            sparams, cfg, dec, caches, jnp.asarray(table), **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_engine_serves_end_to_end_with_pallas(self, tiny):
        """Interpret-mode Pallas decode through the full engine (the CI
        fast lane's 'backend runs green' check)."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=64,
                     forced_mode="fp8", kv_planar=True,
                     attn_backend="pallas", prefix_cache=False)
        eng.submit(Request("r0", list(range(5, 18)), max_new=3))
        fin = eng.run()
        assert len(fin) == 1 and len(fin[0].output) == 3
        assert all(0 <= t < cfg.vocab_size for t in fin[0].output)


class TestMeshStats:
    def test_stats_count_logical_steps_under_serving_mesh(self, tiny):
        """Satellite of the sharded-serving refactor: `Engine.stats`
        accounts LOGICAL steps, so every dispatch and h2d counter must be
        identical between mesh=None and a serving mesh. A 1-device mesh
        exercises the full sharded path (committed shardings, pinned
        control operands, the mesh_context dispatch wrapper) without
        needing forced devices, so this guards the accounting in the
        default tier-1 lane; tests/test_mesh_serving.py repeats the
        assertion at mesh size 4."""
        from repro.launch.mesh import make_serving_mesh
        cfg, sparams = tiny

        def serve(mesh):
            eng = Engine(cfg, sparams, n_slots=8, capacity=64,
                         forced_mode="fp16", chunk_tokens=512,
                         prefix_cache=False, mesh=mesh)
            for i, p in enumerate(PROMPTS):
                eng.submit(Request(f"r{i}", p, max_new=3))
            fin = eng.run()
            return {r.request_id: r.output for r in fin}, eng

        ref, eref = serve(None)
        got, egot = serve(make_serving_mesh(1))
        assert got == ref
        assert egot.stats == eref.stats, (eref.stats, egot.stats)
        assert egot.stats["prefill_dispatches"] == 1
        # per-step normalization the benchmarks report
        assert egot.stats["prefill_dispatches"] \
            == eref.stats["prefill_dispatches"]
        assert egot.stats["h2d_bytes"] == eref.stats["h2d_bytes"]
