"""Tiered KV: stable prefix hashing, host-offload spill/restore, and the
restart-persistent prefix store.

Covers the PR-9 tentpole end to end:

* `_chain_hash`/`_ROOT_HASH` are stable blake2b content digests — two
  processes with DIFFERENT `PYTHONHASHSEED`s agree on every chain hash
  (the old process-salted `hash()` could never be persisted or shared).
* Spill/restore bit-exactness: serving with the host tier on is
  bit-identical to serving with it off when recompute happens in the
  same precision mode, and bit-identical to an ample-pool engine (whose
  blocks are never evicted at all) across an fp8 -> fp16 mode switch —
  the case where recompute is NOT a valid baseline, because KV written
  in fp8 mode legitimately differs from KV recomputed in fp16.
* Planar (NestedKV) pools restore the fp8 hi plane eagerly and lo
  planes lazily on the first FP16-mode touch.
* The RestorePolicy SLO guard: max_queue_bytes=0 bounces every host
  match to recompute (counted, outputs unchanged) and a per-step byte
  cap spreads a big restore over steps without deadlock.
* `Engine(persist_dir=...)` + `save_prefix_store()` survive a REAL
  engine restart (subprocess): the second process gets host-tier prefix
  hits and emits identical tokens.
* `Engine.run(max_iters=...)` raises on exhaustion unless
  `allow_partial=True`, recording `stats["iters_exhausted"]`, and
  `trace.rate_stats`/`azure_like` bucket arrivals without the padded
  final bucket or past-the-end arrivals.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core.policy import RestorePolicy, SLOConfig
from repro.models import model as M
from repro.models.convert import to_serving
from repro.serving import trace
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import _ROOT_HASH, _chain_hash


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


def _mk(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("n_slots", 2)
    kw.setdefault("capacity", 128)
    kw.setdefault("forced_mode", "fp16")
    kw.setdefault("block_size", 16)
    kw.setdefault("n_blocks", 11)
    kw.setdefault("chunk_tokens", 64)
    kw.setdefault("debug_invariants", True)
    return Engine(cfg, params, **kw)


def _sys_prompts(cfg):
    rng = np.random.default_rng(0)
    return (rng.integers(1, cfg.vocab_size, size=96).tolist(),
            rng.integers(1, cfg.vocab_size, size=96).tolist())


def _burst(cfg, sysp, tag, n=3, max_new=6):
    return [Request(f"{tag}{i}",
                    sysp + np.random.default_rng(7 * i + 1)
                    .integers(1, cfg.vocab_size, size=8).tolist(), max_new)
            for i in range(n)]


def _serve_phases(e, cfg, phases):
    """phases: [(tag, sys_prompt, mode|None), ...] — serve each burst to
    completion, switching forced_mode when given."""
    for tag, sysp, mode in phases:
        if mode is not None:
            e.forced_mode = mode
        for r in _burst(cfg, sysp, tag):
            e.submit(r)
        e.run(max_iters=800)
    return {r.request_id: tuple(r.output) for r in e.finished}


# =============================================================================
# stable chain hashes (the tentpole's prerequisite bugfix)
# =============================================================================

_HASH_SNIPPET = textwrap.dedent("""
    import json, sys
    from repro.serving.kvcache import _ROOT_HASH, _chain_hash
    h1 = _chain_hash(_ROOT_HASH, tuple(range(16)))
    h2 = _chain_hash(h1, tuple(range(16, 32)))
    print(json.dumps([_ROOT_HASH, h1, h2]))
""")


class TestStableHash:
    def test_digest_properties(self):
        h = _chain_hash(_ROOT_HASH, (1, 2, 3))
        assert isinstance(h, int)
        assert h == _chain_hash(_ROOT_HASH, (1, 2, 3))
        assert h != _chain_hash(_ROOT_HASH, (1, 2, 4))
        assert h != _chain_hash(h, (1, 2, 3))
        # int64 range: the digest must fit the block-table/index plumbing
        assert -(2**63) <= h < 2**63
        assert -(2**63) <= _ROOT_HASH < 2**63

    def test_cross_process_stability_under_different_hashseed(self):
        """The old process-salted hash() gave each PYTHONHASHSEED its own
        chain hashes, so a persisted index could never round-trip. The
        blake2b digests must agree across processes with different
        seeds — this is what makes `persist_dir` possible at all."""
        outs = []
        for seed in ("1", "4242"):
            env = dict(os.environ,
                       PYTHONHASHSEED=seed,
                       PYTHONPATH="src" + os.pathsep
                       + os.environ.get("PYTHONPATH", ""))
            r = subprocess.run([sys.executable, "-c", _HASH_SNIPPET],
                               capture_output=True, text=True, env=env,
                               check=True)
            outs.append(json.loads(r.stdout))
        assert outs[0] == outs[1], outs
        # and the parent process (whatever ITS seed is) agrees too
        h1 = _chain_hash(_ROOT_HASH, tuple(range(16)))
        h2 = _chain_hash(h1, tuple(range(16, 32)))
        assert outs[0] == [_ROOT_HASH, h1, h2]


# =============================================================================
# spill / restore correctness through the real engine
# =============================================================================

@pytest.mark.slow
class TestSpillRestore:
    def test_fp16_bit_exact_host_on_off(self, tiny):
        """Same-mode recompute IS a valid baseline in fp16: an 11-block
        pool forces sys1's blocks out when sys2 arrives (spill), and the
        third burst re-admits sys1 from the host tier (restore). Every
        token must match the host-off engine, which recomputes instead."""
        cfg, _ = tiny
        sys1, sys2 = _sys_prompts(cfg)
        phases = [("a", sys1, None), ("b", sys2, None), ("c", sys1, None)]
        e_on = _mk(tiny)
        outs_on = _serve_phases(e_on, cfg, phases)
        assert e_on.stats["spilled_blocks"] > 0, e_on.tiered_stats()
        assert e_on.stats["restored_blocks"] > 0, e_on.tiered_stats()
        assert e_on.blocks.prefix_stats["host_hit_blocks"] > 0
        # flat (non-planar) pools restore every plane eagerly: a full
        # spill->restore round trip moves the same bytes both ways
        ts = e_on.tiered_stats()
        if ts["restored_blocks"] == ts["spilled_blocks"]:
            assert ts["restored_bytes"] == ts["spilled_bytes"], ts
        e_off = _mk(tiny, host_offload=False)
        outs_off = _serve_phases(e_off, cfg, phases)
        assert outs_on == outs_off
        assert e_off.stats["spilled_blocks"] == 0
        assert e_off.tiered_stats()["enabled"] is False

    def test_preempt_spill_restore_matches_ample_pool(self, tiny):
        """Concurrent overload: more work than the pool can hold keeps
        preempting the youngest sequence; its released prefix blocks
        spill on eviction and restore on re-admission. The ample-pool
        engine (nothing ever evicted, no preemption pressure from the
        tier) is the ground truth."""
        cfg, _ = tiny
        sys1, _ = _sys_prompts(cfg)
        def serve(**kw):
            e = _mk(tiny, **kw)
            for r in _burst(cfg, sys1, "p", n=5, max_new=24):
                e.submit(r)
            e.run(max_iters=2000)
            return e, {r.request_id: tuple(r.output) for r in e.finished}
        e_tier, outs_tier = serve(n_blocks=11, capacity=192)
        _, outs_ample = serve(n_blocks=64, capacity=192)
        assert outs_tier == outs_ample
        assert e_tier.stats["preemptions"] > 0, e_tier.stats

    def test_planar_lazy_lo_on_fp8_to_fp16_switch(self, tiny):
        """NestedKV planar pools: fp8-mode serving restores hi planes
        only (half the h2d), and the first FP16-mode step lazily lands
        the lo planes of every hi-only-restored block. Baseline is the
        ample-pool engine — recompute is NOT valid here, because blocks
        written under fp8 activations differ from fp16-recomputed ones
        (true for plain device prefix hits too)."""
        cfg, _ = tiny
        sys1, sys2 = _sys_prompts(cfg)
        phases = [("a", sys1, "fp8"), ("b", sys2, None), ("c", sys1, None),
                  ("d", sys2, "fp16")]
        e = _mk(tiny, kv_planar=True, forced_mode="fp8")
        outs_tier = _serve_phases(e, cfg, phases)
        ts = e.tiered_stats()
        assert ts["restored_blocks"] > 0 and ts["lo_lazy_blocks"] > 0, ts
        # hi-plane-only eager restore really halves the h2d per block:
        # the lazy lo completion of each block costs the same bytes the
        # eager hi restore did (planar planes are same-shape uint8)
        per_block_hi = ts["restored_bytes"] // ts["restored_blocks"]
        assert ts["lo_lazy_bytes"] == ts["lo_lazy_blocks"] * per_block_hi, ts
        e2 = _mk(tiny, kv_planar=True, forced_mode="fp8", n_blocks=64)
        outs_ample = _serve_phases(e2, cfg, phases)
        assert outs_tier == outs_ample

    def test_slo_guard_falls_back_to_recompute(self, tiny):
        """max_queue_bytes=0 disables host matching: every would-be host
        hit is counted as a fallback and recomputed — outputs identical
        to the host-off engine, tier still fills (persistence path)."""
        cfg, _ = tiny
        sys1, sys2 = _sys_prompts(cfg)
        phases = [("a", sys1, None), ("b", sys2, None), ("c", sys1, None)]
        e = _mk(tiny, restore_policy=RestorePolicy(max_queue_bytes=0))
        outs = _serve_phases(e, cfg, phases)
        ts = e.tiered_stats()
        assert ts["restored_blocks"] == 0, ts
        assert ts["restore_fallbacks"] > 0, ts
        assert ts["spilled_blocks"] > 0, ts
        outs_off = _serve_phases(_mk(tiny, host_offload=False), cfg, phases)
        assert outs == outs_off

    def test_tiny_per_step_grant_spreads_restores_without_deadlock(
            self, tiny):
        """A 1-byte per-step grant forces the liveness floor: the drain
        still takes one block per step, so gated rows always make
        progress and outputs stay bit-exact."""
        cfg, _ = tiny
        sys1, sys2 = _sys_prompts(cfg)
        phases = [("a", sys1, None), ("b", sys2, None), ("c", sys1, None)]
        e = _mk(tiny, restore_policy=RestorePolicy(
            max_restore_bytes_per_step=1))
        outs = _serve_phases(e, cfg, phases)
        assert e.stats["restored_blocks"] > 0, e.tiered_stats()
        outs_off = _serve_phases(_mk(tiny, host_offload=False), cfg, phases)
        assert outs == outs_off

    def test_host_pool_cap_drops_oldest_and_stays_correct(self, tiny):
        """A one-block host budget keeps dropping entries (drop-oldest,
        pinned entries skipped); misses just recompute."""
        cfg, _ = tiny
        sys1, sys2 = _sys_prompts(cfg)
        phases = [("a", sys1, None), ("b", sys2, None), ("c", sys1, None)]
        # one block's bytes: 2 planes (k,v) f16 * layers * 16 tokens
        e_probe = _mk(tiny)
        cap = max(e_probe._eager_block_bytes.values())
        e = _mk(tiny, host_bytes=cap)
        outs = _serve_phases(e, cfg, phases)
        assert e.blocks.host.bytes <= cap
        assert e.blocks.host.stats["dropped_blocks"] > 0
        outs_off = _serve_phases(_mk(tiny, host_offload=False), cfg, phases)
        assert outs == outs_off

    def test_from_slo_budget_scales_with_tpot(self):
        p = RestorePolicy.from_slo(SLOConfig(tpot_ms=10.0), h2d_gbps=10.0,
                                   frac=0.5, queue_steps=4)
        assert p.max_restore_bytes_per_step == int(0.010 * 0.9 * 0.5
                                                   * 10e9)
        assert p.max_queue_bytes == p.max_restore_bytes_per_step * 4
        assert p.admit(0) and not p.admit(p.max_queue_bytes)


# =============================================================================
# restart persistence (subprocess: a REAL second process)
# =============================================================================

_PERSIST_SNIPPET = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.models.convert import to_serving
    from repro.serving.engine import Engine, Request

    persist_dir, save = sys.argv[1], sys.argv[2] == "save"
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    e = Engine(cfg, params, n_slots=2, capacity=128, forced_mode="fp16",
               block_size=16, n_blocks=24, chunk_tokens=64,
               debug_invariants=True, persist_dir=persist_dir)
    rng = np.random.default_rng(0)
    sysp = rng.integers(1, cfg.vocab_size, size=96).tolist()
    for i in range(3):
        tail = np.random.default_rng(7 * i + 1).integers(
            1, cfg.vocab_size, size=8).tolist()
        e.submit(Request(f"r{i}", sysp + tail, 6))
    e.run(max_iters=800)
    if save:
        e.save_prefix_store()
    print(json.dumps({
        "outputs": {r.request_id: r.output for r in e.finished},
        "host_hit_blocks": e.blocks.prefix_stats["host_hit_blocks"],
        "hit_tokens": e.blocks.prefix_stats["hit_tokens"],
        "restored_blocks": e.stats["restored_blocks"]}))
""")


@pytest.mark.slow
class TestRestartPersistence:
    def test_prefix_hits_survive_engine_restart(self, tmp_path):
        """Two separate python processes, different hash seeds: the
        first serves a shared-prefix burst and persists its prefix
        store; the second loads it, re-admits the system prompt from
        the host tier WITHOUT recomputing it, and emits byte-identical
        tokens."""
        def run(save, seed):
            env = dict(os.environ,
                       PYTHONHASHSEED=seed,
                       PYTHONPATH="src" + os.pathsep
                       + os.environ.get("PYTHONPATH", ""))
            r = subprocess.run(
                [sys.executable, "-c", _PERSIST_SNIPPET, str(tmp_path),
                 "save" if save else "load"],
                capture_output=True, text=True, env=env)
            assert r.returncode == 0, r.stderr[-2000:]
            return json.loads(r.stdout.splitlines()[-1])
        first = run(save=True, seed="1")
        assert (tmp_path / "prefix_store.npz").exists()
        assert (tmp_path / "prefix_store.json").exists()
        assert first["host_hit_blocks"] == 0
        second = run(save=False, seed="31337")
        # prefix hit-rate SURVIVED the restart: the system prompt came
        # back from the persisted host tier, not from recompute
        assert second["host_hit_blocks"] > 0, second
        assert second["restored_blocks"] > 0, second
        assert second["hit_tokens"] >= first["hit_tokens"], (first, second)
        assert second["outputs"] == first["outputs"]

    def test_meta_mismatch_ignores_store(self, tiny, tmp_path):
        """A store persisted under one layout must never be joined with
        a different one: corrupt the meta fingerprint and the load must
        be a clean no-op."""
        cfg, _ = tiny
        sys1, _ = _sys_prompts(cfg)
        e = _mk(tiny, n_blocks=24, persist_dir=str(tmp_path))
        for r in _burst(cfg, sys1, "s"):
            e.submit(r)
        e.run(max_iters=800)
        assert e.save_prefix_store() > 0
        meta = json.loads((tmp_path / "prefix_store.json").read_text())
        meta["block_size"] = 8
        (tmp_path / "prefix_store.json").write_text(json.dumps(meta))
        e2 = _mk(tiny, n_blocks=24, persist_dir=str(tmp_path))
        assert len(e2.blocks.host) == 0
        assert e2._load_prefix_store(str(tmp_path)) == 0


# =============================================================================
# run(max_iters) exhaustion + trace stats bugfixes (satellites)
# =============================================================================

class TestRunExhaustion:
    def test_raises_and_records_when_cap_hit(self, tiny):
        cfg, _ = tiny
        e = _mk(tiny, host_offload=False)
        e.submit(Request("r0", list(range(1, 40)), 64))
        with pytest.raises(RuntimeError, match="max_iters"):
            e.run(max_iters=3)
        assert e.stats["iters_exhausted"] > 0
        # allow_partial: same situation reports instead of raising
        e2 = _mk(tiny, host_offload=False)
        e2.submit(Request("r0", list(range(1, 40)), 64))
        done = e2.run(max_iters=3, allow_partial=True)
        assert e2.stats["iters_exhausted"] > 0
        assert len(done) == 0

    def test_clean_completion_leaves_zero(self, tiny):
        e = _mk(tiny, host_offload=False)
        e.submit(Request("r0", list(range(1, 20)), 4))
        done = e.run(max_iters=400)
        assert [r.request_id for r in done] == ["r0"]
        assert e.stats["iters_exhausted"] == 0


class TestTraceStats:
    def test_rate_stats_unbiased_mean(self):
        reqs = [trace.TraceRequest(t + 0.5, 8, 8) for t in range(10)]
        s = trace.rate_stats(reqs, duration_s=10.0)
        # 10 requests over 10 s is EXACTLY 1 req/s — the old padded
        # bucket reported 10/11 and a phantom min of 0
        assert s["mean_rate"] == pytest.approx(1.0)
        assert s["min_rate"] == 1.0
        assert s["max_rate"] == 1.0

    def test_rate_stats_fractional_duration_and_edge_arrival(self):
        s = trace.rate_stats([trace.TraceRequest(2.5, 8, 8),
                              trace.TraceRequest(3.0, 8, 8)], 3.0)
        assert s["max_rate"] == 2.0          # both land in the last bin
        assert s["mean_rate"] == pytest.approx(2 / 3)

    def test_azure_like_never_past_duration(self):
        for seed in range(5):
            reqs = trace.azure_like(duration_s=7.0, seed=seed)
            assert all(r.arrival_s <= 7.0 for r in reqs)
            trace.rate_stats(reqs, 7.0)      # in-range for every bucket
