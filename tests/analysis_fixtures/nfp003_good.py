"""NFP003 fixture (good): the cache key passes through a pow2/bucket
helper, bounding the number of compiled variants."""

import jax

_CACHE = {}


def _get_step(n: int):
    key = (n,)
    if key not in _CACHE:
        _CACHE[key] = jax.jit(lambda x: x[:n])
    return _CACHE[key]


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def apply(x, n: int):
    return _get_step(_pow2_bucket(n))(x)
