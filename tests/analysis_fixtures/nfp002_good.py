"""NFP002 fixture (good): the donated name is rebound from the call's
result before any further read — the canonical donation idiom."""

import jax

_step = jax.jit(lambda params, batch: params, donate_argnums=(0,))


def train(params, batch):
    params = _step(params, batch)
    return params.sum()
