"""Malformed-directive fixture: each line below is rejected (NFP000) —
suppressions without a reason rot into unreviewable noise."""

X = 1  # nfp: ignore[NFP001]
Y = 2  # nfp: ignore[NFP999] not a real rule id
Z = 3  # nfp: frobnicate
