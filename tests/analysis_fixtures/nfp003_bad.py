"""NFP003 fixture (bad): a jit-executable cache keyed on a raw int —
one compile (and one resident executable) per distinct value."""

import jax

_CACHE = {}


def _get_step(n: int):
    key = (n,)
    if key not in _CACHE:
        _CACHE[key] = jax.jit(lambda x: x[:n])
    return _CACHE[key]


def apply(x, n: int):
    return _get_step(n)(x)                     # expect: NFP003
