"""NFP002 fixture (bad): a buffer read after being passed at a
donate_argnums position — XLA may already have reused its pages."""

import jax

_step = jax.jit(lambda params, batch: params, donate_argnums=(0,))


def train(params, batch):
    new_params = _step(params, batch)
    stale = params.sum()                       # expect: NFP002
    return new_params, stale
