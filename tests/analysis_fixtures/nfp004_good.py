"""NFP004 fixture (good): grid-arity index maps, a divisibility assert
backing the floor-divided grid, and a caller-threaded interpret flag."""

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def scale_rows(x, bm: int = 128, interpret: bool = False):
    m, n = x.shape
    assert m % bm == 0, "row tiles must divide the array"
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
