"""NFP005 fixture (good): metadata checks (`.ndim`, `in`) stay Python
control flow — they are static under tracing — while value-dependent
branches go through `jnp.where`."""

import jax
import jax.numpy as jnp


@jax.jit
def normalize(x):
    assert x.ndim in (1, 2)
    total = jnp.sum(x)
    if total.ndim == 0:
        total = jnp.reshape(total, (1,))
    safe = jnp.where(total > 0, total, 1.0)
    return x / safe
