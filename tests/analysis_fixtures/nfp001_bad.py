"""NFP001 fixture (bad): host syncs inside a hot-path function.

Never imported — parsed by repro-lint in tests/test_analysis.py; the
`# expect:` trailing comments are the golden finding locations.
"""

import numpy as np
import jax
import jax.numpy as jnp


# nfp: hot-path
def decode_step(state, tokens):
    logits = jnp.dot(state, tokens)
    best = logits.item()                       # expect: NFP001
    host = np.asarray(logits)                  # expect: NFP001
    score = float(logits)                      # expect: NFP001
    jax.device_get(logits)                     # expect: NFP001
    return best, host, score
