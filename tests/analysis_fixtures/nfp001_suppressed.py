"""Suppression fixture: one real NFP001 finding, acknowledged with an
inline ignore directive carrying its required reason."""

import jax.numpy as jnp


# nfp: hot-path
def decode_step(state):
    logits = jnp.sum(state)
    # nfp: ignore[NFP001] fixture: demonstrates the suppression syntax
    return float(logits)
