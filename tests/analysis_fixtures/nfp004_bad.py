"""NFP004 fixture (bad): pallas_call hygiene violations — an index-map
whose arity drifted from the grid, a floor-divided grid size with no
divisibility assert, and no `interpret=` fallback."""

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def scale_rows(x, bm: int = 128):
    m, n = x.shape
    return pl.pallas_call(                     # expect: NFP004
        _copy_kernel,
        grid=(m // bm,),                       # expect: NFP004
        in_specs=[pl.BlockSpec((bm, n), lambda i, j: (i, 0))],  # expect: NFP004
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
