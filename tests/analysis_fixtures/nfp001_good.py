"""NFP001 fixture (good): the hot path defers every device->host pull
to its single declared `# nfp: sync-point` function, which the
reachability walk never enters."""

import numpy as np
import jax.numpy as jnp


# nfp: hot-path
def decode_step(state, tokens):
    logits = jnp.dot(state, tokens)
    return finalize(logits)


# nfp: sync-point
def finalize(logits):
    return np.asarray(logits)
