"""NFP005 fixture (bad): Python `if`/`while`/`assert` on traced values
inside a jitted body — TracerBoolConversionError at trace time."""

import jax
import jax.numpy as jnp


@jax.jit
def normalize(x):
    total = jnp.sum(x)
    if total > 0:                              # expect: NFP005
        x = x / total
    while jnp.any(x > 1.0):                    # expect: NFP005
        x = x * 0.5
    assert jnp.all(x <= 1.0)                   # expect: NFP005
    return x
