"""Per-kernel correctness sweeps: Pallas (interpret=True) vs ref.py oracles.

Sweeps shapes (divisible and ragged), block shapes, and dtypes per the
repo testing policy. Reconstruction inside the kernel must be bit-exact,
so the nestedfp16 kernel's only tolerance is f32 accumulation order.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import nestedfp as nf
from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.f16_matmul import f16_matmul
from repro.kernels.nestedfp16_matmul import nestedfp16_matmul, _reconstruct_f16
from repro.kernels.nestedfp8_matmul import nestedfp8_matmul, nestedfp8_matmul_fused_quant

RNG = np.random.RandomState(42)


def _mk(m, k, n, wmax=1.6):
    x = RNG.uniform(-2, 2, (m, k)).astype(np.float16)
    w = RNG.uniform(-wmax, wmax, (k, n)).astype(np.float16)
    return jnp.asarray(x), jnp.asarray(w)


SHAPES = [(128, 256, 128), (256, 512, 256), (128, 768, 384), (384, 256, 640)]
BLOCKS = [(128, 128, 256), (128, 128, 128), (64, 128, 128)]


class TestReconstructInKernelHelper:
    def test_tile_reconstruction_bit_exact(self):
        w = jnp.asarray(RNG.uniform(-1.75, 1.75, (64, 64)).astype(np.float16))
        u, l = nf.encode(w)
        np.testing.assert_array_equal(
            np.asarray(_reconstruct_f16(u, l)).view(np.uint16),
            np.asarray(w).view(np.uint16))


class TestNestedFP16Kernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("block", BLOCKS[:2])
    def test_matches_oracle(self, shape, block):
        m, k, n = shape
        x, w = _mk(m, k, n)
        u, l = nf.encode(w)
        got = nestedfp16_matmul(x, u, l, block=block, interpret=True)
        want = ref.nestedfp16_matmul_ref(x, u, l)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_equals_plain_f16_gemm_exactly_same_blocking(self):
        """Reconstruction is lossless => same block schedule gives IDENTICAL
        results to the plain f16 kernel on the original weights."""
        x, w = _mk(128, 256, 128)
        u, l = nf.encode(w)
        a = nestedfp16_matmul(x, u, l, block=(128, 128, 128), interpret=True)
        b = f16_matmul(x, w, block=(128, 128, 128), interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("shape", [(100, 200, 90), (1, 300, 77), (33, 64, 128)])
    def test_ragged_shapes_via_ops_wrapper(self, shape):
        m, k, n = shape
        x, w = _mk(m, k, n)
        u, l = nf.encode(w)
        got = ops.matmul_nested_f16(x, u, l, backend="pallas_interpret",
                                    block=(64, 128, 128))
        want = ref.nestedfp16_matmul_ref(x, u, l)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_batched_leading_dims(self):
        x = jnp.asarray(RNG.uniform(-1, 1, (4, 8, 256)).astype(np.float16))
        w = jnp.asarray(RNG.uniform(-1, 1, (256, 128)).astype(np.float16))
        u, l = nf.encode(w)
        got = ops.matmul_nested_f16(x, u, l, backend="pallas_interpret")
        want = ref.nestedfp16_matmul_ref(x.reshape(-1, 256), u, l).reshape(4, 8, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestNestedFP8Kernel:
    @pytest.mark.parametrize("shape", SHAPES[:3])
    def test_matches_oracle(self, shape):
        m, k, n = shape
        x, w = _mk(m, k, n)
        u, _ = nf.encode(w)
        xq, scale = quant.quantize_act_per_tensor(x)
        got = nestedfp8_matmul(xq, u, jnp.atleast_1d(scale), interpret=True,
                               block=(128, 128, 128))
        want = ref.nestedfp8_matmul_ref(xq, u, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_fp8_path_close_to_f16_truth(self):
        """End-to-end quant error sanity: fp8 result within a few % of f16."""
        x, w = _mk(128, 512, 128, wmax=1.0)
        u, l = nf.encode(w)
        xq, scale = quant.quantize_act_per_tensor(x)
        got = np.asarray(nestedfp8_matmul(xq, u, jnp.atleast_1d(scale),
                                          interpret=True, block=(128, 128, 128)))
        truth = np.asarray(ref.matmul_f16_ref(x, w))
        denom = np.maximum(np.abs(truth), 1.0)
        assert np.median(np.abs(got - truth) / denom) < 0.05

    def test_per_token_scales_ref_matches_pallas(self):
        """(M, 1) row scales: the pallas wrapper dequants OUTSIDE the
        kernel (scalar ks=1 inside) and must agree with the ref oracle's
        native broadcast."""
        x, w = _mk(64, 256, 128)
        u, _ = nf.encode(w)
        xq, scale = quant.quantize_act_per_token(x)
        a = ops.matmul_nested_fp8(xq, u, scale, backend="ref")
        b = ops.matmul_nested_fp8(xq, u, scale, backend="pallas_interpret",
                                  block=(64, 128, 128))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)

    def test_per_token_row_independence(self):
        """The serving engine's batch-invariance contract: a row's fp8
        result must not change with the rest of the batch (per-tensor
        scales fail this by construction)."""
        x, w = _mk(8, 256, 128)
        u, _ = nf.encode(w)

        def run(xx):
            from repro.core import linear
            p = linear.NestedLinearParams.from_weights(w)
            return np.asarray(linear.nested_linear(
                p, xx, mode="fp8", backend="ref", act_quant="per_token",
                out_dtype=jnp.float32))

        full = run(x)
        solo = run(x[:1] * 100.0)  # blow up row 0's amax...
        batched = run(jnp.concatenate([x[:1] * 100.0, x[1:]], axis=0))
        np.testing.assert_array_equal(batched[0], solo[0])
        np.testing.assert_array_equal(batched[1:], full[1:],
                                      "row 0's scale leaked into the batch")

    def test_fused_quant_variant_matches_unfused(self):
        x, w = _mk(128, 256, 128)
        u, _ = nf.encode(w)
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        fused = nestedfp8_matmul_fused_quant(x, u, jnp.atleast_1d(amax),
                                             interpret=True, block=(128, 128, 128))
        xq, scale = quant.quantize_act_per_tensor(x)
        unfused = nestedfp8_matmul(xq, u, jnp.atleast_1d(scale),
                                   interpret=True, block=(128, 128, 128))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-5, atol=1e-4)


class TestRefBackendDispatch:
    def test_ops_ref_backend_matches_interpret(self):
        x, w = _mk(64, 256, 128)
        u, l = nf.encode(w)
        a = ops.matmul_nested_f16(x, u, l, backend="ref")
        b = ops.matmul_nested_f16(x, u, l, backend="pallas_interpret",
                                  block=(64, 128, 128))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)

    def test_exception_layer_plain_f16(self):
        x, w = _mk(64, 128, 64, wmax=3.0)   # not applicable
        t = nf.NestedTensor.from_f16(w)
        assert t.is_exception
        got = ops.matmul_f16(x, t.read_f16(), backend="pallas_interpret",
                             block=(64, 64, 128))
        want = ref.matmul_f16_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestEncodeKernel:
    """Offline encode Pallas kernel vs the jnp encoder (exact)."""

    @pytest.mark.parametrize("shape", [(256, 256), (512, 768)])
    def test_matches_jnp_encode(self, shape):
        from repro.kernels.nestedfp_encode import nestedfp_encode
        w = jnp.asarray(RNG.uniform(-1.75, 1.75, shape).astype(np.float16))
        uk, lk = nestedfp_encode(w, interpret=True)
        ur, lr = nf.encode(w)
        np.testing.assert_array_equal(np.asarray(uk), np.asarray(ur))
        np.testing.assert_array_equal(np.asarray(lk), np.asarray(lr))

    def test_roundtrip_through_kernel(self):
        from repro.kernels.nestedfp_encode import nestedfp_encode
        w = jnp.asarray(RNG.uniform(-1.5, 1.5, (256, 512)).astype(np.float16))
        u, l = nestedfp_encode(w, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(nf.decode(u, l)).view(np.uint16),
            np.asarray(w).view(np.uint16))
