"""Suite-wide wiring: run every engine test with the BlockManager
runtime sanitizer on.

``NFP_DEBUG=1`` makes ``Engine.step`` call
``BlockManager.check_invariants()`` after every step (refcounts,
free-list consistency, device-table mirror), so any paging bug trips
at the step that introduces it instead of whichever later test
happens to call ``check_invariants()`` by hand.  ``setdefault`` keeps
an explicit ``NFP_DEBUG=0`` from the environment respected.
"""

import os

os.environ.setdefault("NFP_DEBUG", "1")
