"""Paged KV cache + chunked prefill: BlockManager invariants, chunked-vs-
monolithic prefill bit-exactness (GQA and MLA latent planes), preemption
correctness (recompute resumes exactly under greedy decoding), MLA and
hybrid descriptor serving through the ONE paged scheduling path, the
paged planar decode kernel, and regression tests for the measured-p90
controller path and the capacity off-by-one."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.base import MLAConfig
from repro.core import nestedfp as nf
from repro.core.policy import DualPrecisionController, SLOConfig
from repro.kernels.planar_decode_attention import paged_planar_decode_attention
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime, attn_core_decode
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import TRASH_BLOCK, BlockManager


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


def _tiny_mla_cfg():
    """deepseek_coder_33b-shaped tiny config (dense llama-arch trunk)
    with DeepSeek MLA attention — the latent-cache serving family."""
    return dataclasses.replace(
        ARCHS["deepseek-coder-33b"].reduced(),
        arch_id="deepseek-coder-33b-mla-reduced",
        mla=MLAConfig(kv_lora_rank=64, q_lora_rank=96, qk_rope_dim=16,
                      qk_nope_dim=32, v_head_dim=32))


@pytest.fixture(scope="module")
def tiny_mla():
    cfg = _tiny_mla_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


@pytest.fixture(scope="module")
def tiny_hybrid():
    cfg = ARCHS["zamba2-2.7b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


@pytest.fixture(scope="module")
def tiny_swa():
    """Reduced gemma3: 2 layers (one local sliding-window, one global),
    window 19 — deliberately odd so it is never block-aligned, and
    smaller than every prompt the sweep uses."""
    cfg = ARCHS["gemma3-1b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


class TestBlockManager:
    def test_allocate_extend_release_conserves_blocks(self):
        bm = BlockManager(n_slots=2, block_size=4, n_blocks=8,
                          max_blocks_per_seq=8)
        a = bm.try_allocate("a", 6, 10)
        assert a is not None and bm.n_free_blocks() == 8
        assert bm.ensure(a, 6)                      # 2 blocks
        assert bm.n_free_blocks() == 6
        assert bm.ensure(a, 6)                      # idempotent
        assert bm.n_free_blocks() == 6
        assert bm.ensure(a, 9)                      # 3rd block
        assert bm.n_free_blocks() == 5
        bm.release(a)
        assert bm.n_free_blocks() == 8 and bm.blocks_in_use() == 0

    def test_trash_block_never_allocated(self):
        bm = BlockManager(2, 4, 6, 8)
        a = bm.try_allocate("a", 4, 4)
        assert bm.ensure(a, 24)
        assert TRASH_BLOCK not in bm.seqs[a].blocks
        tab = bm.table(a)
        assert (tab[:6] > 0).all() and (tab[6:] == TRASH_BLOCK).all()

    def test_ensure_all_or_nothing(self):
        bm = BlockManager(1, 4, 3, 8)
        a = bm.try_allocate("a", 4, 4)
        assert bm.ensure(a, 12)                     # all 3 blocks
        assert not bm.ensure(a, 16)                 # pool dry
        assert bm.n_free_blocks() == 0 and len(bm.seqs[a].blocks) == 3

    def test_capacity_and_pool_guards(self):
        bm = BlockManager(1, 4, 16, 4)              # per-seq cap 16 tokens
        with pytest.raises(ValueError):
            bm.try_allocate("a", 12, 8)             # 20 > 16
        bm2 = BlockManager(1, 4, 2, 8)              # pool smaller than seq
        with pytest.raises(ValueError):
            bm2.try_allocate("a", 8, 8)             # 4 blocks > 2-block pool

    def test_admission_watermark(self):
        bm = BlockManager(4, 4, 4, 4)
        a = bm.try_allocate("a", 12, 4)             # 3 of 4 blocks
        assert bm.ensure(a, 12)
        assert bm.try_allocate("b", 8, 4) is None   # needs 2, only 1 free
        assert bm.try_allocate("c", 4, 4) is not None

    def test_youngest_tracks_admission_order(self):
        bm = BlockManager(3, 4, 12, 4)
        a = bm.try_allocate("a", 4, 4)
        b = bm.try_allocate("b", 4, 4)
        assert bm.youngest() == b
        bm.release(b)
        assert bm.youngest() == a
        c = bm.try_allocate("c", 4, 4)
        assert bm.youngest() == c
        bm.release(a), bm.release(c)
        assert bm.youngest() is None


@pytest.mark.slow
class TestChunkedPrefill:
    def test_chunked_matches_monolithic_bit_exact(self, tiny):
        """FP16 logits of chunked prefill must be BIT-identical to a
        single-chunk prefill: both round-trip K/V through the same f16
        paged pool and gather keys in logical order, so chunking cannot
        perturb the arithmetic."""
        cfg, sparams = tiny
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        bs, mb = 16, 4
        prompt = list(range(5, 18))                 # 13 tokens, odd split
        plen = len(prompt)
        table = np.zeros((1, mb), np.int32)
        table[0, 0], table[0, 1] = 1, 2

        def run(chunks):
            caches = M.init_paged_cache(cfg, n_total_blocks=9, block_size=bs)
            out, start = None, 0
            for take in chunks:
                toks = np.zeros((1, 16), np.int32)
                toks[0, :take] = prompt[start: start + take]
                out, caches = M.paged_step(
                    rt, sparams, cfg, jnp.asarray(toks), caches,
                    jnp.asarray(table),
                    q_offset=jnp.asarray([start], jnp.int32),
                    kv_len=jnp.asarray([start + take], jnp.int32),
                    block_size=bs,
                    logit_position=jnp.asarray([take - 1], jnp.int32),
                    return_logits=True)
                start += take
            assert start == plen
            return np.asarray(out)

        mono = run([plen])
        assert (run([4, 4, 5]) == mono).all()       # crosses a block boundary
        assert (run([1] * plen) == mono).all()      # token-at-a-time

    def test_engine_chunked_equals_unchunked(self, tiny):
        cfg, sparams = tiny
        prompts = [list(range(3, 40)), list(range(60, 75))]
        outs = []
        for chunk in (8, 512):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", chunk_tokens=chunk)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=5))
            outs.append({r.request_id: r.output for r in eng.run()})
        assert outs[0] == outs[1]

    def test_chunked_prefill_interleaves_with_decode(self, tiny):
        """A long queued prompt must not stall active decodes: with a
        small chunk budget, r0 keeps emitting tokens on iterations where
        r1's prompt is still prefilling."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=4, capacity=128,
                     forced_mode="fp16", chunk_tokens=8)
        eng.submit(Request("r0", list(range(4, 12)), max_new=12))
        eng.step()                                  # r0 prefilled + admitted
        eng.submit(Request("r1", list(range(2, 66)), max_new=2))  # 64 tokens
        decoded_during_prefill = 0
        while eng.prefilling or eng.queue:
            n0 = len(eng.active[0].output) if 0 in eng.active else None
            eng.step()
            if n0 is not None and 0 in eng.active \
                    and len(eng.active[0].output) > n0:
                decoded_during_prefill += 1
        assert decoded_during_prefill >= 3, \
            "decode stalled while the long prompt prefilled"
        fin = {r.request_id: r for r in eng.run()}
        assert len(fin["r0"].output) == 12 and len(fin["r1"].output) == 2


@pytest.mark.slow
class TestPreemption:
    def test_forced_preemption_completes_all_requests(self, tiny):
        """Scarce pool forces decode-growth preemption; recompute must
        resume exactly — outputs identical to an ample-pool run."""
        cfg, sparams = tiny
        prompts = [list(range(4, 12)), list(range(30, 38)),
                   list(range(90, 98))]

        def run(n_blocks):
            eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                         forced_mode="fp16", block_size=4,
                         n_blocks=n_blocks)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=16))
            fin = {r.request_id: r.output for r in eng.run()}
            assert eng.blocks.n_free_blocks() == eng.blocks.n_blocks
            return fin, eng.stats["preemptions"]

        ample, p0 = run(n_blocks=24)                # 3 seqs * 6 blocks
        scarce, p1 = run(n_blocks=10)
        assert p0 == 0 and p1 >= 1, (p0, p1)
        assert ample == scarce, "preemption changed generated tokens"
        assert all(len(o) == 16 for o in scarce.values())

    def test_admission_is_block_driven(self, tiny):
        """Free slots alone no longer admit: a queued request waits until
        blocks free up, then completes."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=4, capacity=32,
                     forced_mode="fp16", block_size=4, n_blocks=8)
        eng.submit(Request("big", list(range(4, 28)), max_new=4))  # 6 blocks
        eng.step()
        assert 0 in {**eng.active, **eng.prefilling}
        eng.submit(Request("waits", list(range(50, 62)), max_new=4))  # 3 blocks
        eng.step()
        assert len(eng.queue) == 1, "admitted without blocks for its prompt"
        fin = {r.request_id: r for r in eng.run()}
        assert set(fin) == {"big", "waits"}
        assert all(len(r.output) == 4 for r in fin.values())


class TestPagedPlanarKernel:
    def _pool_from_logical(self, rng, b, cap, hkv, d, bs, mb, nb):
        k = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
        v = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
        tables = np.zeros((b, mb), np.int32)
        ids = list(range(1, nb))
        rng.shuffle(ids)
        pool_k = np.zeros((nb, bs, hkv, d), np.float16)
        pool_v = np.zeros((nb, bs, hkv, d), np.float16)
        t = 0
        for bb in range(b):
            for m in range(mb):
                pid = ids[t]
                t += 1
                tables[bb, m] = pid
                pool_k[pid] = np.asarray(k[bb, m * bs: (m + 1) * bs])
                pool_v[pid] = np.asarray(v[bb, m * bs: (m + 1) * bs])
        return k, v, jnp.asarray(tables), jnp.asarray(pool_k), jnp.asarray(pool_v)

    @pytest.mark.parametrize("shape", [(2, 8, 4, 64), (1, 16, 2, 64)])
    def test_fp16_matches_oracle_through_shuffled_pool(self, shape):
        b, h, hkv, d = shape
        bs, mb = 128, 4
        nb = b * mb + 1
        rng = np.random.RandomState(11)
        cap = mb * bs
        q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
        k, v, tables, pk, pv = self._pool_from_logical(
            rng, b, cap, hkv, d, bs, mb, nb)
        lens = jnp.asarray(rng.randint(1, cap, b), jnp.int32)
        k_hi, k_lo = nf.split_bytes(pk)
        v_hi, v_lo = nf.split_bytes(pv)
        got = paged_planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo,
                                            tables, lens, interpret=True)
        want = attn_core_decode(q[:, None], k, v, lens)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_fp8_reads_hi_plane_only(self):
        b, h, hkv, d = 2, 8, 4, 64
        bs, mb = 128, 2
        nb = b * mb + 1
        rng = np.random.RandomState(5)
        cap = mb * bs
        q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
        k, v, tables, pk, pv = self._pool_from_logical(
            rng, b, cap, hkv, d, bs, mb, nb)
        lens = jnp.asarray([cap, 37], jnp.int32)
        k_hi, k_lo = nf.split_bytes(pk)
        v_hi, v_lo = nf.split_bytes(pv)
        got = paged_planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo,
                                            tables, lens, fp8=True,
                                            interpret=True)
        k8 = nf.e5m2_view(nf.split_bytes(k)[0], jnp.float16)
        v8 = nf.e5m2_view(nf.split_bytes(v)[0], jnp.float16)
        want = attn_core_decode(q[:, None], k8, v8, lens)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


class _FakeClock:
    def __init__(self):
        self.t, self.dt = 0.0, 0.0

    def __call__(self):
        self.t += self.dt
        return self.t


class TestEngineRegressions:
    def test_measured_p90_enters_and_exits_fp8(self, tiny):
        """engine.py used to pass measured_step_ms=None, so the
        controller's p90 fallback was dead code. With wall time recorded,
        slow measured steps must force FP8 and fast ones must release it
        — even though the PREDICTED cost never breaches the SLO."""
        cfg, sparams = tiny
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=2),
            fp16_ms_per_token=1e-4, fp8_ms_per_token=5e-5,
            fixed_overhead_ms=0.0)
        clock = _FakeClock()
        eng = Engine(cfg, sparams, n_slots=2, capacity=128,
                     controller=ctrl, clock=clock)
        eng.submit(Request("r0", list(range(5, 13)), max_new=100))
        while eng.queue or eng.active or eng.prefilling:
            # each step makes a handful of clock calls; 20 ms per call
            # puts measured step time far beyond the 30 ms budget
            clock.dt = 0.020 if eng.iteration < 20 else 1e-7
            eng.step()
        assert "fp8" in ctrl.history, "measured p90 never engaged FP8"
        assert ctrl.history[-1] == "fp16", "never recovered from FP8"
        assert len(eng.finished) == 1 and len(eng.finished[0].output) == 100

    def test_capacity_boundary_not_truncated(self, tiny):
        """prompt+max_new == capacity must yield ALL max_new tokens; the
        old `length + 1 >= capacity` retire check cut the last one."""
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=32,
                     forced_mode="fp16")
        eng.submit(Request("r0", list(range(4, 12)), max_new=24))   # 8+24=32
        fin = eng.run()
        assert len(fin) == 1
        assert len(fin[0].output) == 24, \
            f"truncated at capacity: got {len(fin[0].output)}/24"

    def test_legacy_fixed_slot_path_retired(self, tiny):
        """ONE scheduling path: the legacy fixed-slot engine path is
        gone — no `_admit_legacy`/`_decode_legacy`/`paged=` switch — and
        every engine instance schedules on a BlockManager."""
        cfg, sparams = tiny
        assert not hasattr(Engine, "_admit_legacy")
        assert not hasattr(Engine, "_decode_legacy")
        with pytest.raises(TypeError):
            Engine(cfg, sparams, n_slots=2, capacity=32, paged=False)
        eng = Engine(cfg, sparams, n_slots=2, capacity=32,
                     forced_mode="fp16")
        assert isinstance(eng.blocks, BlockManager)

    def test_empty_prompt_rejected(self, tiny):
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=32,
                     forced_mode="fp16")
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request("r0", [], max_new=4))

    def test_queue_is_a_deque(self, tiny):
        cfg, sparams = tiny
        import collections
        eng = Engine(cfg, sparams, n_slots=2, capacity=32,
                     forced_mode="fp16")
        assert isinstance(eng.queue, collections.deque)


class TestPrefixCacheBlockManager:
    """COW prefix caching invariants at the BlockManager level."""

    def _commit_seq(self, bm, rid, tokens):
        idx = bm.try_allocate(rid, len(tokens), 4)
        assert idx is not None
        assert bm.attach_prefix(idx, tokens) >= 0
        assert bm.ensure(idx, len(tokens))
        bm.commit(idx, len(tokens), tokens)
        bm.check_invariants()
        return idx

    def test_release_parks_registered_blocks_in_lru(self):
        bm = BlockManager(2, 4, 8, 8, prefix_cache=True)
        toks = list(range(10, 22))                   # 3 full blocks
        a = self._commit_seq(bm, "a", toks)
        assert bm.blocks_in_use() == 3 and bm.n_cached_blocks() == 0
        bm.release(a)
        bm.check_invariants()
        # decref, not free: blocks stay cached and reusable
        assert bm.n_cached_blocks() == 3
        assert bm.n_free_blocks() == 8               # still all allocatable
        assert bm.lookup_prefix(toks) == 12

    def test_attach_shares_and_increfs(self):
        bm = BlockManager(3, 4, 8, 8, prefix_cache=True)
        toks = list(range(30, 42))
        a = self._commit_seq(bm, "a", toks)
        b = bm.try_allocate("b", len(toks), 4)
        matched = bm.attach_prefix(b, toks + [1, 2])
        bm.check_invariants()
        assert matched == 12
        assert bm.seqs[b].blocks == bm.seqs[a].blocks
        assert bm._ref[0][bm.seqs[a].blocks[0]] == 2
        # shared blocks count once toward pool usage
        assert bm.blocks_in_use() == 3
        bm.release(a)
        bm.check_invariants()
        assert bm._ref[0][bm.seqs[b].blocks[0]] == 1
        assert bm.n_cached_blocks() == 0             # still referenced by b

    def test_cow_fork_gives_private_copy(self):
        bm = BlockManager(3, 4, 8, 8, prefix_cache=True)
        toks = list(range(50, 58))                   # 2 full blocks
        a = self._commit_seq(bm, "a", toks)
        b = bm.try_allocate("b", len(toks), 4)
        bm.attach_prefix(b, toks)
        shared_tail = bm.seqs[b].blocks[1]
        pairs = bm.cow_for_write(b, 7, 8)            # rewrite last token
        bm.check_invariants()
        assert pairs and pairs[0][:2] == (0, shared_tail)
        assert bm.seqs[b].blocks[1] != shared_tail   # private now
        assert bm.seqs[a].blocks[1] == shared_tail   # holder untouched
        assert bm._ref[0][shared_tail] == 1 \
            and bm._ref[0][bm.seqs[b].blocks[1]] == 1
        assert bm.cow_for_write(b, 7, 8) == []       # idempotent: now private

    def test_lru_reclaim_before_preemption(self):
        """A dry free list reclaims cached blocks (evicting their index
        entries) rather than failing ensure."""
        bm = BlockManager(3, 4, 4, 4, prefix_cache=True)
        a = self._commit_seq(bm, "a", list(range(8)))    # 2 blocks
        bm.release(a)
        assert bm.n_cached_blocks() == 2
        b = bm.try_allocate("b", 16, 0)
        assert bm.attach_prefix(b, list(range(100, 116))) == 0
        assert bm.ensure(b, 16)                      # needs all 4 blocks
        bm.check_invariants()
        assert bm.n_cached_blocks() == 0 and bm.prefix_stats["evictions"] == 2
        assert bm.lookup_prefix(list(range(8))) == 0  # evicted from index

    def test_randomized_op_soup_invariants(self):
        """Refcounts never negative, shared blocks never on the free
        list, tables always consistent — under a random mix of admission
        with sharing, growth, COW, commit, and release."""
        rng = np.random.RandomState(0)
        bm = BlockManager(4, 4, 12, 6, prefix_cache=True)
        streams = [list(range(s, s + 20)) for s in (0, 0, 40, 80)]
        live: dict[int, list] = {}
        for _ in range(300):
            op = rng.randint(4)
            if op == 0 and bm.n_free_slots():
                toks = streams[rng.randint(len(streams))]
                idx = bm.try_allocate(f"r{_}", len(toks), 4,
                                      bm.prefix_admit_discount(toks))
                if idx is not None:
                    matched = bm.attach_prefix(idx, toks)
                    live[idx] = toks
                    assert matched % bm.block_size == 0
            elif op == 1 and live:
                idx = list(live)[rng.randint(len(live))]
                n = min(len(live[idx]),
                        len(bm.seqs[idx].blocks) * bm.block_size
                        + rng.randint(1, 6))
                if bm.ensure(idx, n):
                    start = rng.randint(n)
                    if bm.cow_for_write(idx, start, n) is not None:
                        bm.commit(idx, n, live[idx])
            elif op == 2 and live:
                idx = list(live)[rng.randint(len(live))]
                bm.release(idx)
                del live[idx]
            else:
                bm.lookup_prefix(streams[rng.randint(len(streams))])
            bm.check_invariants()
            assert all(r >= 0 for grp in bm._ref for r in grp)
        for idx in list(live):
            bm.release(idx)
        bm.check_invariants()
        assert bm.blocks_in_use() == 0


@pytest.mark.slow
class TestPrefixCacheEngine:
    def test_prefix_reuse_reduces_prefill_and_blocks(self, tiny):
        """N requests sharing a >=2-block prefix: prefilled tokens and
        peak blocks_in_use drop vs caching off; outputs bit-exact; stats
        report the hit."""
        cfg, sparams = tiny
        shared = list(range(7, 23))                  # 2 blocks of 8
        prompts = [shared + [100 + i, 200 + i] for i in range(4)]
        runs = {}
        for pc in (True, False):
            # chunk budget of one prompt per step: later requests admit
            # only after earlier ones committed their blocks, so the
            # shared prefix is actually in the index when they match
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", block_size=8,
                         chunk_tokens=18, prefix_cache=pc)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=8))
            # footprint compared at the same occupancy point: the first
            # step where all 4 requests are resident and decoding
            resident_blocks = None
            while eng.queue or eng.active or eng.prefilling:
                eng.step()
                if resident_blocks is None and len(eng.active) == 4:
                    resident_blocks = eng.blocks.blocks_in_use()
            runs[pc] = ({r.request_id: r.output for r in eng.finished},
                        eng.stats["chunk_tokens"], resident_blocks,
                        eng.prefix_cache_stats())
        out_on, prefill_on, blocks_on, stats_on = runs[True]
        out_off, prefill_off, blocks_off, _ = runs[False]
        assert out_on == out_off, "prefix caching changed greedy outputs"
        assert prefill_on < prefill_off, \
            f"no prefill saving: {prefill_on} vs {prefill_off}"
        assert blocks_on is not None and blocks_off is not None \
            and blocks_on < blocks_off, \
            f"no block saving: {blocks_on} vs {blocks_off}"
        assert stats_on["hit_rate"] > 0 and stats_on["blocks_saved"] >= 6

    @pytest.mark.parametrize("planar", [False, True])
    def test_bit_exact_with_caching_on_vs_off(self, tiny, planar):
        """Greedy outputs with prefix caching on == off, planar and
        non-planar NestedKV layouts."""
        cfg, sparams = tiny
        shared = list(range(11, 27))
        prompts = [shared + list(range(40 + 3 * i, 43 + 3 * i))
                   for i in range(3)]
        outs = []
        for pc in (True, False):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", block_size=8, chunk_tokens=24,
                         kv_planar=planar, prefix_cache=pc)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=4))
            outs.append({r.request_id: r.output for r in eng.run()})
        assert outs[0] == outs[1]

    def test_cow_write_into_live_shared_block_is_isolated(self, tiny):
        """A fully-cached block-aligned prompt re-admitted while the
        original holder still decodes must COW-fork the tail block: both
        sequences produce exactly their solo outputs."""
        cfg, sparams = tiny
        shared = list(range(7, 31))                  # 3 aligned blocks of 8

        def solo(prompt, max_new):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", block_size=8,
                         prefix_cache=False)
            eng.submit(Request("s", prompt, max_new=max_new))
            return eng.run()[0].output

        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16", block_size=8)
        eng.submit(Request("a", shared, max_new=20))
        eng.step(), eng.step()          # a prefilled, blocks live + shared
        eng.submit(Request("b", shared, max_new=4))
        fin = {r.request_id: r.output for r in eng.run()}
        eng.blocks.check_invariants()
        assert eng.prefix_cache_stats()["cow_forks"] >= 1
        assert fin["a"] == solo(shared, 20), "holder corrupted by COW write"
        assert fin["b"] == solo(shared, 4)

    def test_preemption_under_sharing_decrefs_correctly(self, tiny):
        """Scarce pool + shared prefixes: preemption decrefs (never
        frees a block another sequence still references) and outputs
        match the ample-pool run exactly."""
        cfg, sparams = tiny
        shared = list(range(4, 12))
        prompts = [shared + list(range(30 + 4 * i, 34 + 4 * i))
                   for i in range(3)]

        def run(n_blocks):
            eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                         forced_mode="fp16", block_size=4,
                         n_blocks=n_blocks, chunk_tokens=12)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=16))
            fin = {r.request_id: r.output for r in eng.run()}
            eng.blocks.check_invariants()
            assert eng.blocks.blocks_in_use() == 0
            return fin, eng.stats["preemptions"]

        ample, p0 = run(24)
        scarce, p1 = run(10)
        assert p1 >= 1, "scarce pool never preempted"
        assert ample == scarce
        assert all(len(o) == 16 for o in scarce.values())

    def test_shared_physical_blocks_transparent_to_planar_kernel(self):
        """Two rows whose block tables point at the SAME physical blocks
        must read identically to rows with duplicated private blocks —
        the gather path makes sharing invisible to the kernel."""
        b, h, hkv, d = 2, 8, 4, 64
        bs, mb = 128, 2
        rng = np.random.RandomState(3)
        pool = rng.randn(mb + 1, bs, hkv, d).astype(np.float16)
        pool_dup = np.concatenate([pool, pool[1:]], axis=0)  # private copies
        q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
        shared_tables = jnp.asarray([[1, 2], [1, 2]], jnp.int32)
        dup_tables = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        lens = jnp.asarray([bs * mb, 57], jnp.int32)
        outs = []
        for pk, tabs in ((pool, shared_tables), (pool_dup, dup_tables)):
            k_hi, k_lo = nf.split_bytes(jnp.asarray(pk))
            outs.append(np.asarray(paged_planar_decode_attention(
                q, k_hi, k_lo, k_hi, k_lo, tabs, lens, interpret=True)))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_cow_fork_is_all_or_nothing(self):
        """A multi-block fork that cannot fully allocate must mutate
        NOTHING: a partial fork would strand (src, dst) pairs whose
        bytes the caller never learns to copy (stale-KV corruption)."""
        bm = BlockManager(3, 4, 5, 5, prefix_cache=True)
        toks = list(range(12))
        a = bm.try_allocate("a", 12, 0)
        bm.attach_prefix(a, toks)
        assert bm.ensure(a, 12)
        bm.commit(a, 12, toks)
        b = bm.try_allocate("b", 12, 0,
                            cached_blocks=bm.prefix_admit_discount(toks))
        assert bm.attach_prefix(b, toks) == 12       # 3 shared blocks
        before = list(bm.seqs[b].blocks)
        assert bm.cow_for_write(b, 0, 12) is None    # needs 3, pool has 2
        assert bm.seqs[b].blocks == before, "partial fork leaked"
        assert bm.prefix_stats["cow_forks"] == 0
        bm.check_invariants()
        pairs = bm.cow_for_write(b, 0, 8)            # 2 of 3 fits
        assert pairs is not None and len(pairs) == 2
        bm.check_invariants()


def _greedy_fixed_slot_reference(cfg, sparams, prompt, n_new):
    """The pre-refactor fixed-slot arithmetic: monolithic M.prefill into
    a capacity-reserved cache + one-token M.decode_step loop."""
    rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
    toks = jnp.asarray([prompt], jnp.int32)
    cap = len(prompt) + n_new + 1
    logits, caches, length = M.prefill(rt, sparams, cfg, {"tokens": toks},
                                       capacity=cap)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for i in range(n_new - 1):
        lg, caches = M.decode_step(
            rt, sparams, cfg, jnp.asarray([[out[-1]]], jnp.int32),
            caches, jnp.int32(length + i))
        out.append(int(np.argmax(np.asarray(lg)[0])))
    return out


class TestMLAPagedServing:
    """MLA latent caches (c_kv + k_rope planes) through the paged path:
    mirrors the GQA chunked-prefill / prefix-cache / preemption cases on
    a deepseek_coder_33b-shaped tiny config with MLA attention."""

    @pytest.mark.slow
    def test_chunked_matches_monolithic_bit_exact(self, tiny_mla):
        """Chunked MLA prefill must be BIT-identical to a single-chunk
        prefill: every chunk runs the same absorbed-latent arithmetic
        over latents round-tripped through the same f16 paged planes."""
        cfg, sparams = tiny_mla
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        bs, mb = 16, 4
        prompt = list(range(5, 18))                 # 13 tokens, odd split
        plen = len(prompt)
        table = np.zeros((1, mb), np.int32)
        table[0, 0], table[0, 1] = 1, 2

        def run(chunks):
            caches = M.init_paged_cache(cfg, n_total_blocks=9, block_size=bs)
            assert set(caches["attn"]) == {"c_kv", "k_rope"}
            out, start = None, 0
            for take in chunks:
                toks = np.zeros((1, 16), np.int32)
                toks[0, :take] = prompt[start: start + take]
                out, caches = M.paged_step(
                    rt, sparams, cfg, jnp.asarray(toks), caches,
                    jnp.asarray(table),
                    q_offset=jnp.asarray([start], jnp.int32),
                    kv_len=jnp.asarray([start + take], jnp.int32),
                    block_size=bs,
                    logit_position=jnp.asarray([take - 1], jnp.int32),
                    return_logits=True)
                start += take
            assert start == plen
            return np.asarray(out)

        mono = run([plen])
        assert (run([4, 4, 5]) == mono).all()       # crosses a block boundary
        assert (run([1] * plen) == mono).all()      # token-at-a-time

    def test_engine_matches_fixed_slot_reference(self, tiny_mla):
        """Acceptance: MLA decode runs through `paged_step` with greedy
        outputs matching the pre-refactor fixed-slot path exactly.
        (Deliberately NOT marked slow — this is the CI fast lane's MLA
        paged smoke test, so descriptor regressions fail in <2 min.)"""
        cfg, sparams = tiny_mla
        prompts = [list(range(5, 18)), list(range(40, 60))]
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16")
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p, max_new=6))
        fin = {r.request_id: r.output for r in eng.run()}
        for i, p in enumerate(prompts):
            ref = _greedy_fixed_slot_reference(cfg, sparams, p, 6)
            assert fin[f"r{i}"] == ref, f"r{i} diverged from fixed-slot ref"

    @pytest.mark.slow
    def test_engine_chunked_equals_unchunked(self, tiny_mla):
        cfg, sparams = tiny_mla
        prompts = [list(range(3, 40)), list(range(60, 75))]
        outs = []
        for chunk in (8, 512):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", chunk_tokens=chunk)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=5))
            outs.append({r.request_id: r.output for r in eng.run()})
        assert outs[0] == outs[1]

    def test_bit_exact_with_prefix_caching_on_vs_off(self, tiny_mla):
        """Greedy outputs with COW prefix caching over LATENT blocks on
        == off, and sharing actually reduces prefilled tokens."""
        cfg, sparams = tiny_mla
        shared = list(range(11, 27))                 # 2 blocks of 8
        prompts = [shared + list(range(40 + 3 * i, 43 + 3 * i))
                   for i in range(3)]
        runs = {}
        for pc in (True, False):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", block_size=8, chunk_tokens=19,
                         prefix_cache=pc)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=4))
            runs[pc] = ({r.request_id: r.output for r in eng.run()},
                        eng.stats["chunk_tokens"], eng.prefix_cache_stats())
        assert runs[True][0] == runs[False][0], \
            "latent-block prefix sharing changed greedy outputs"
        assert runs[True][1] < runs[False][1], "no prefill saving"
        assert runs[True][2]["blocks_saved"] >= 2

    @pytest.mark.slow
    def test_preemption_reproduces_ample_pool_outputs(self, tiny_mla):
        """Scarce latent pool forces decode-growth preemption; recompute
        must resume exactly — outputs identical to an ample-pool run."""
        cfg, sparams = tiny_mla
        prompts = [list(range(4, 12)), list(range(30, 38)),
                   list(range(90, 98))]

        def run(n_blocks):
            eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                         forced_mode="fp16", block_size=4,
                         n_blocks=n_blocks)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=16))
            fin = {r.request_id: r.output for r in eng.run()}
            eng.blocks.check_invariants()
            assert eng.blocks.n_free_blocks() == eng.blocks.n_blocks
            return fin, eng.stats["preemptions"]

        ample, p0 = run(n_blocks=24)
        scarce, p1 = run(n_blocks=10)
        assert p0 == 0 and p1 >= 1, (p0, p1)
        assert ample == scarce, "preemption changed generated tokens"
        assert all(len(o) == 16 for o in scarce.values())

    @pytest.mark.slow
    def test_free_block_frac_sees_latent_pressure(self, tiny_mla):
        """The controller's memory-pressure FP8 trigger must fire on MLA
        latent-block exhaustion (latency thresholds out of reach)."""
        cfg, sparams = tiny_mla
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=1e9, hysteresis_steps=2,
                      free_block_frac_min=0.3),
            fp16_ms_per_token=1e-9, fp8_ms_per_token=1e-9)
        eng = Engine(cfg, sparams, n_slots=4, capacity=32,
                     controller=ctrl, block_size=4, n_blocks=10)
        for i in range(3):
            eng.submit(Request(f"r{i}", list(range(4 + 8 * i, 12 + 8 * i)),
                               max_new=16))
        eng.run()
        assert "fp8" in ctrl.history, \
            "MLA latent-block headroom never engaged FP8"


class TestSlidingWindowPagedServing:
    """gemma3-style sliding-window serving: per-layer-group block tables
    with mid-generation window-slide reclamation of local-layer blocks.
    The tiny config's window (19) is odd — never block-aligned — and
    smaller than every prompt here, so every test crosses window
    boundaries mid-block."""

    def test_descriptor_carries_window_groups(self, tiny_swa):
        cfg, _ = tiny_swa
        assert cfg.sliding_window == 19, "reduced window must be odd"
        desc = M.cache_descriptor(cfg)
        assert [g.name for g in desc.groups] == ["global", "local"]
        assert desc.group_windows == (None, 19)
        # reduced gemma3: layer 1 global (swa_pattern 2), layer 0 local
        assert list(desc.layer_group_map(cfg.n_layers)) == [1, 0]

    def test_engine_matches_fixed_slot_reference(self, tiny_swa):
        """Acceptance: with window reclamation, prefix caching, and the
        paged path all enabled, greedy outputs match the fixed-slot
        reference exactly — and reclamation actually fired.
        (Deliberately NOT marked slow — this is the CI fast lane's
        gemma3 paged smoke test.)"""
        cfg, sparams = tiny_swa
        prompt = list(range(4, 84))                  # 80 tokens >= 4x window
        eng = Engine(cfg, sparams, n_slots=2, capacity=96,
                     forced_mode="fp16", block_size=8)
        eng.submit(Request("r0", prompt, max_new=6))
        fin = eng.run()
        assert fin[0].output == _greedy_fixed_slot_reference(
            cfg, sparams, prompt, 6), "diverged from fixed-slot reference"
        assert eng.stats["window_reclaimed_blocks"] > 0, \
            "long prompt never slid any local block"
        eng.blocks.check_invariants()
        assert eng.blocks.blocks_in_use() == 0

    @pytest.mark.slow
    def test_chunked_matches_monolithic_bit_exact(self, tiny_swa):
        """Chunked prefill of a prompt >2x the window must be
        BIT-identical to monolithic: local layers mask to the same
        window regardless of chunk split."""
        cfg, sparams = tiny_swa
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        bs, mb = 16, 4
        prompt = list(range(5, 50))                  # 45 tokens
        plen = len(prompt)
        table = np.zeros((1, mb), np.int32)
        table[0] = [1, 2, 3, 4]

        def run(chunks):
            caches = M.init_paged_cache(cfg, n_total_blocks=9, block_size=bs)
            out, start = None, 0
            for take in chunks:
                width = take if take > 16 else 16
                toks = np.zeros((1, width), np.int32)
                toks[0, :take] = prompt[start: start + take]
                out, caches = M.paged_step(
                    rt, sparams, cfg, jnp.asarray(toks), caches,
                    jnp.asarray(table),
                    q_offset=jnp.asarray([start], jnp.int32),
                    kv_len=jnp.asarray([start + take], jnp.int32),
                    block_size=bs,
                    logit_position=jnp.asarray([take - 1], jnp.int32),
                    return_logits=True)
                start += take
            assert start == plen
            return np.asarray(out)

        mono = run([plen])
        # 19-token window crosses both chunk seams and block boundaries
        assert (run([16, 16, 13]) == mono).all()
        assert (run([7, 9, 11, 9, 9]) == mono).all()
        assert (run([1] * plen) == mono).all()

    @pytest.mark.slow
    def test_window_reclaim_on_equals_off_and_frees_blocks(self, tiny_swa):
        """Acceptance criterion: with an ample pool, window-slide
        reclamation changes NOTHING about the outputs while steady-state
        decode holds strictly fewer live blocks than the
        no-reclamation baseline."""
        cfg, sparams = tiny_swa
        prompts = [list(range(4, 84)), list(range(100, 180))]  # 80 >= 4x19

        def run(reclaim):
            eng = Engine(cfg, sparams, n_slots=2, capacity=96,
                         forced_mode="fp16", block_size=8,
                         window_reclaim=reclaim)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=10))
            steady = []
            while eng.queue or eng.active or eng.prefilling:
                eng.step()
                if len(eng.active) == 2 and not eng.prefilling:
                    steady.append(eng.blocks.blocks_in_use())
            fin = {r.request_id: r.output for r in eng.finished}
            eng.blocks.check_invariants()
            return fin, steady, eng.stats["window_reclaimed_blocks"]

        out_on, steady_on, freed_on = run(True)
        out_off, steady_off, freed_off = run(False)
        assert out_on == out_off, "window reclamation changed outputs"
        assert freed_on > 0 and freed_off == 0
        assert len(steady_on) == len(steady_off)
        assert steady_on[-1] < steady_off[-1], \
            f"no steady-state saving: {steady_on[-1]} vs {steady_off[-1]}"
        # every steady-decode step holds no MORE blocks than the baseline
        assert all(a <= b for a, b in zip(steady_on, steady_off))

    @pytest.mark.slow
    def test_prefix_caching_on_off_bit_exact_with_sharing(self, tiny_swa):
        """Group-aware prefix caching: a second request sharing a
        40-token prefix attaches the global chain plus only the local
        blocks inside its resume window, and greedy outputs are
        bit-exact with caching on vs off."""
        cfg, sparams = tiny_swa
        shared = list(range(7, 47))                  # 5 blocks of 8
        prompts = [shared + list(range(60 + 5 * i, 65 + 5 * i))
                   for i in range(2)]
        runs = {}
        for pc in (True, False):
            # chunk budget 24: r0 commits 3 full prefix blocks before r1
            # admits, and r0 has not yet slid past r1's resume lookback
            eng = Engine(cfg, sparams, n_slots=3, capacity=96,
                         forced_mode="fp16", block_size=8, chunk_tokens=24,
                         prefix_cache=pc)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=6))
            runs[pc] = ({r.request_id: r.output for r in eng.run()},
                        eng.prefix_cache_stats())
            eng.blocks.check_invariants()
        assert runs[True][0] == runs[False][0], \
            "window-aware prefix sharing changed greedy outputs"
        assert runs[True][1]["blocks_saved"] >= 4, \
            "global+local prefix blocks never shared"

    @pytest.mark.slow
    def test_preemption_under_sharing_matches_ample_pool(self, tiny_swa):
        """Scarce pool + shared prefixes + sliding windows: preemption
        and requeue (re-attach pre-slides the local group) reproduce the
        ample-pool outputs exactly."""
        cfg, sparams = tiny_swa
        shared = list(range(4, 12))
        prompts = [shared + list(range(30 + 4 * i, 42 + 4 * i))
                   for i in range(3)]                # 20 tokens each

        def run(n_blocks):
            eng = Engine(cfg, sparams, n_slots=3, capacity=48,
                         forced_mode="fp16", block_size=4,
                         n_blocks=n_blocks, chunk_tokens=20)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=16))
            fin = {r.request_id: r.output for r in eng.run()}
            eng.blocks.check_invariants()
            assert eng.blocks.blocks_in_use() == 0
            return fin, eng.stats["preemptions"]

        ample, p0 = run(n_blocks=64)
        scarce, p1 = run(n_blocks=18)
        assert p0 == 0 and p1 >= 1, (p0, p1)
        assert ample == scarce, "preemption changed generated tokens"
        assert all(len(o) == 16 for o in scarce.values())
        # acceptance: bit-exact against the fixed-slot reference with
        # reclamation, prefix caching, and preemption all enabled
        for i, p in enumerate(prompts):
            assert scarce[f"r{i}"] == _greedy_fixed_slot_reference(
                cfg, sparams, p, 16), f"r{i} diverged from fixed-slot ref"


class TestHybridPagedServing:
    """zamba2-class hybrid descriptor: paged shared-attention blocks +
    slot-resident SSM state, scheduled through the same paged path."""

    def test_descriptor_shape(self, tiny_hybrid):
        cfg, sparams = tiny_hybrid
        desc = M.cache_descriptor(cfg)
        assert desc.kind == "hybrid" and not desc.prefix_cacheable
        assert {p.name for p in desc.planes} == {"k", "v"}
        assert {p.name for p in desc.slot_planes} == \
            {"conv_x", "conv_bc", "ssm"}
        assert desc.bytes_per_token > 0 and desc.bytes_per_slot > 0
        # shared-attn planes page one logical layer per application group
        assert desc.planes[0].n_layers == cfg.n_layers // cfg.attn_every

    @pytest.mark.slow
    def test_batched_matches_solo(self, tiny_hybrid):
        """Batched hybrid serving == solo serving per request (state
        rows are independent; inactive-row masking must hold)."""
        cfg, sparams = tiny_hybrid
        prompts = [list(range(4 + 10 * i, 13 + 10 * i)) for i in range(3)]

        def solo(p):
            eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                         forced_mode="fp16", chunk_tokens=512)
            eng.submit(Request("s", p, max_new=6))
            return eng.run()[0].output

        eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                     forced_mode="fp16", chunk_tokens=512)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p, max_new=6))
        fin = {r.request_id: r.output for r in eng.run()}
        for i, p in enumerate(prompts):
            assert fin[f"r{i}"] == solo(p), f"r{i} corrupted by batching"

    @pytest.mark.slow
    def test_decode_interleaves_with_chunked_prefill(self, tiny_hybrid):
        """SSM-state rows mid-prefill must not corrupt active decodes
        (and vice versa): r0 keeps decoding while r1's long prompt
        prefills in small exact-length chunks, and r0's output equals
        its solo run."""
        cfg, sparams = tiny_hybrid

        def solo(p, max_new):
            eng = Engine(cfg, sparams, n_slots=4, capacity=128,
                         forced_mode="fp16", chunk_tokens=512)
            eng.submit(Request("s", p, max_new=max_new))
            return eng.run()[0].output

        eng = Engine(cfg, sparams, n_slots=4, capacity=128,
                     forced_mode="fp16", chunk_tokens=8)
        p0 = list(range(4, 12))
        eng.submit(Request("r0", p0, max_new=12))
        eng.step()                                  # r0 prefilled + admitted
        eng.submit(Request("r1", list(range(2, 66)), max_new=2))
        fin = {r.request_id: r for r in eng.run()}
        assert len(fin["r0"].output) == 12 and len(fin["r1"].output) == 2
        assert fin["r0"].output == solo(p0, 12), \
            "prefill chunks of r1 corrupted r0's slot state"

    @pytest.mark.slow
    def test_preemption_completes_all_requests(self, tiny_hybrid):
        """Scarce shared-attn pool forces preemption; every request
        still completes with its full token budget and slot state is
        released (SSD chunk-boundary rounding makes token-level
        bit-exactness a non-goal here, unlike attention families)."""
        cfg, sparams = tiny_hybrid
        eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                     forced_mode="fp16", block_size=4, n_blocks=10)
        for i in range(3):
            eng.submit(Request(f"r{i}", list(range(4 + 9 * i, 12 + 9 * i)),
                               max_new=16))
        fin = {r.request_id: r for r in eng.run()}
        assert eng.stats["preemptions"] >= 1, "scarce pool never preempted"
        assert len(fin) == 3
        assert all(len(r.output) == 16 for r in fin.values())
        eng.blocks.check_invariants()
        assert eng.blocks.blocks_in_use() == 0
        assert eng.slot_state.n_free() == eng.slot_state.n_slots

    def test_slot_state_claimed_in_lockstep(self, tiny_hybrid):
        """The SlotManager side of the hybrid descriptor mirrors the
        BlockManager's slot assignment while sequences are live."""
        cfg, sparams = tiny_hybrid
        eng = Engine(cfg, sparams, n_slots=3, capacity=32,
                     forced_mode="fp16")
        assert eng.slot_state is not None
        assert not eng.blocks.prefix_cache, \
            "recurrent state cannot be prefix-cached"
        for i in range(2):
            eng.submit(Request(f"r{i}", list(range(4, 12)), max_new=8))
        eng.step()
        live = {i for i, s in enumerate(eng.blocks.seqs) if s is not None}
        assert set(eng.slot_state.active()) == live
        for i in live:
            assert eng.slot_state.slots[i].request_id \
                == eng.blocks.seqs[i].request_id
        eng.run()
        assert eng.slot_state.n_free() == eng.slot_state.n_slots

    @pytest.mark.slow
    def test_free_block_frac_sees_hybrid_pressure(self, tiny_hybrid):
        """Shared-attention block exhaustion on a hybrid model must
        engage the controller's FP8 memory-pressure trigger."""
        cfg, sparams = tiny_hybrid
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=1e9, hysteresis_steps=2,
                      free_block_frac_min=0.3),
            fp16_ms_per_token=1e-9, fp8_ms_per_token=1e-9)
        eng = Engine(cfg, sparams, n_slots=4, capacity=32,
                     controller=ctrl, block_size=4, n_blocks=10)
        for i in range(3):
            eng.submit(Request(f"r{i}", list(range(4 + 8 * i, 12 + 8 * i)),
                               max_new=16))
        eng.run()
        assert "fp8" in ctrl.history, \
            "hybrid shared-attn headroom never engaged FP8"


class TestSSMPagedScheduling:
    """Pure-SSM descriptor: slot-resident state only; block tables
    degenerate to token accounting but scheduling is the same path."""

    def test_engine_serves_mamba2(self):
        cfg = ARCHS["mamba2-2.7b"].reduced()
        sparams = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
        desc = M.cache_descriptor(cfg)
        assert desc.kind == "ssm" and not desc.planes
        assert desc.bytes_per_token == 0 and desc.bytes_per_slot > 0
        eng = Engine(cfg, sparams, n_slots=2, capacity=32,
                     forced_mode="fp16", chunk_tokens=512)
        for i in range(3):                           # recycles slots
            eng.submit(Request(f"r{i}", list(range(4 + 7 * i, 12 + 7 * i)),
                               max_new=4))
        fin = eng.run()
        assert len(fin) == 3
        assert all(len(r.output) == 4 for r in fin)
