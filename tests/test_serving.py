"""Serving engine tests: continuous batching correctness, dual-precision
switching, slot recycling, SLO simulation."""

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core.policy import DualPrecisionController, SLOConfig, StepObservation
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import SlotManager
from repro.serving import simulate, trace


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


def _greedy_reference(cfg, sparams, prompt, n_new, mode="fp16"):
    """Unbatched reference generation."""
    rt = Runtime(mode=mode, backend="ref", dtype=jax.numpy.float32)
    toks = jax.numpy.asarray([prompt], dtype=jax.numpy.int32)
    cap = len(prompt) + n_new + 1
    logits, caches, length = M.prefill(rt, sparams, cfg, {"tokens": toks},
                                       capacity=cap)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for i in range(n_new - 1):
        lg, caches = M.decode_step(
            rt, sparams, cfg,
            jax.numpy.asarray([[out[-1]]], dtype=jax.numpy.int32),
            caches, jax.numpy.int32(length + i))
        out.append(int(np.argmax(np.asarray(lg)[0])))
    return out


@pytest.mark.slow
class TestEngine:
    def test_single_request_matches_unbatched_reference(self, tiny):
        cfg, sparams = tiny
        prompt = list(range(5, 13))
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16")
        eng.submit(Request("r0", prompt, max_new=6))
        fin = eng.run()
        assert len(fin) == 1
        ref = _greedy_reference(cfg, sparams, prompt, 6)
        assert fin[0].output == ref

    def test_concurrent_requests_isolated(self, tiny):
        """Batched serving must give identical outputs to solo serving."""
        cfg, sparams = tiny
        prompts = [list(range(3, 11)), list(range(40, 48)),
                   list(range(100, 108))]
        eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                     forced_mode="fp16")
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p, max_new=5))
        fin = {r.request_id: r for r in eng.run()}
        assert len(fin) == 3
        for i, p in enumerate(prompts):
            ref = _greedy_reference(cfg, sparams, p, 5)
            assert fin[f"r{i}"].output == ref, f"request r{i} corrupted"

    def test_slot_recycling_more_requests_than_slots(self, tiny):
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=64,
                     forced_mode="fp16")
        for i in range(5):
            eng.submit(Request(f"r{i}", list(range(4, 10)), max_new=3))
        fin = eng.run()
        assert len(fin) == 5
        assert all(len(r.output) == 3 for r in fin)

    def test_fp8_mode_runs_and_differs_slightly(self, tiny):
        cfg, sparams = tiny
        prompt = list(range(7, 15))
        a = _greedy_reference(cfg, sparams, prompt, 4, mode="fp16")
        b = _greedy_reference(cfg, sparams, prompt, 4, mode="fp8")
        assert len(a) == len(b) == 4  # same shape; tokens may differ slightly

    def test_stop_tokens_retire_early(self, tiny):
        """EOS emission retires the request mid-stream: output is the
        greedy prefix through the stop token, and the slot frees for
        the next request (no speculation involved)."""
        cfg, sparams = tiny
        prompt = list(range(5, 13))
        ref = _greedy_reference(cfg, sparams, prompt, 6)
        eng = Engine(cfg, sparams, n_slots=1, capacity=64,
                     forced_mode="fp16")
        eng.submit(Request("r0", prompt, max_new=6, stop_tokens=(ref[2],)))
        eng.submit(Request("r1", prompt, max_new=6))
        fin = {r.request_id: r.output for r in eng.run()}
        assert fin["r0"] == ref[:3], "did not stop AT the stop token"
        assert fin["r1"] == ref, "slot not recycled after EOS retirement"

    def test_controller_switches_under_load(self, tiny):
        cfg, sparams = tiny
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=2),
            fp16_ms_per_token=1.0, fp8_ms_per_token=0.5,
            fixed_overhead_ms=1.0)
        eng = Engine(cfg, sparams, n_slots=8, capacity=64, controller=ctrl)
        for i in range(8):
            eng.submit(Request(f"r{i}", list(range(4, 60)), max_new=4))
        eng.run()
        assert "fp8" in ctrl.history, "controller never engaged FP8 under load"


class TestSlotManager:
    def test_allocate_release(self):
        sm = SlotManager(2, 128)
        a = sm.try_allocate("a", 10, 5)
        b = sm.try_allocate("b", 10, 5)
        assert {a, b} == {0, 1}
        assert sm.try_allocate("c", 10, 5) is None
        sm.release(a)
        assert sm.try_allocate("c", 10, 5) == a

    def test_capacity_guard(self):
        sm = SlotManager(1, 16)
        with pytest.raises(ValueError):
            sm.try_allocate("a", 20, 5)


class TestController:
    def test_hysteresis(self):
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=3),
            fp16_ms_per_token=1.0, fp8_ms_per_token=0.4)
        # overload: predicted fp16 latency 2+100 > 30
        m = ctrl.decide(StepObservation(100, 0, None))
        assert m == "fp8"
        modes = [ctrl.decide(StepObservation(1, 0, 5.0)) for _ in range(5)]
        assert modes[:2] == ["fp8", "fp8"], "left fp8 before dwell expired"
        assert modes[-1] == "fp16", "never returned to fp16"

    def test_p90_tracking_triggers(self):
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3), fp16_ms_per_token=0.01,
            fp8_ms_per_token=0.005)
        for _ in range(20):
            ctrl.decide(StepObservation(1, 0, measured_step_ms=50.0))
        assert ctrl.mode == "fp8"

    def test_p90_samples_tagged_per_mode(self):
        """Regression: measured samples must land in the deque of the
        mode that RAN the measured step. A shared deque let fast FP8
        dwell samples drag the 'FP16' p90 back under budget, bouncing
        the controller to FP16 one slow step after every switch."""
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=2),
            fp16_ms_per_token=1e-4, fp8_ms_per_token=5e-5)
        for _ in range(8):                       # slow FP16 steps
            ctrl.decide(StepObservation(1, 0, 50.0))
        assert ctrl.mode == "fp8", "measured p90 never engaged FP8"
        ctrl.decide(StepObservation(1, 0, 5.0))  # fast step, ran in FP8
        assert list(ctrl._recent["fp8"]) == [5.0], \
            "FP8-mode sample not tagged to the FP8 deque"
        assert 5.0 not in ctrl._recent["fp16"], \
            "FP8 dwell sample polluted the FP16 evidence"

    def test_p90_stale_evidence_decays_and_recovers(self):
        """Measured-only overload traps the controller in FP8 (FP8 steps
        add no FP16 samples, so the breaching p90 can never refresh);
        the decay must drain the stale window — one pre-overload sample
        per FP8 re-probe cycle — until a now-fast workload HOLDS FP16."""
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=2),
            fp16_ms_per_token=1e-4, fp8_ms_per_token=5e-5)
        for _ in range(8):
            ctrl.decide(StepObservation(1, 0, 50.0))
        assert ctrl.mode == "fp8"
        modes = [ctrl.decide(StepObservation(1, 0, 5.0)) for _ in range(40)]
        assert "fp16" in modes, "stale p90 evidence pinned FP8 forever"
        assert all(m == "fp16" for m in modes[-10:]), \
            "stale window never drained — controller still flapping"
        assert 50.0 not in list(ctrl._recent["fp16"])[1:], \
            "fresh FP16 samples interleaved with undrained stale ones"

    def test_free_block_headroom_triggers_fp8(self):
        """MorphServe-style memory-pressure signal: scarce KV headroom
        forces FP8 even when predicted/measured latency is comfortably
        inside the SLO; recovery honours the hysteresis dwell."""
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=33.3, hysteresis_steps=3,
                      free_block_frac_min=0.15),
            fp16_ms_per_token=1e-4, fp8_ms_per_token=5e-5)
        assert ctrl.decide(StepObservation(1, 0, 1.0,
                                           free_block_frac=0.5)) == "fp16"
        assert ctrl.decide(StepObservation(1, 0, 1.0,
                                           free_block_frac=0.05)) == "fp8"
        # pressure persists: dwell keeps refreshing, mode stays fp8
        for _ in range(5):
            assert ctrl.decide(StepObservation(
                1, 0, 1.0, free_block_frac=0.05)) == "fp8"
        # pressure clears: dwell must expire before fp16 returns
        modes = [ctrl.decide(StepObservation(1, 0, 1.0,
                                             free_block_frac=0.9))
                 for _ in range(4)]
        assert modes[:2] == ["fp8", "fp8"], "left fp8 before dwell expired"
        assert modes[-1] == "fp16", "never recovered after headroom returned"
        # non-paged engines pass None: signal must be inert
        ctrl2 = DualPrecisionController(
            SLOConfig(tpot_ms=33.3), fp16_ms_per_token=1e-4,
            fp8_ms_per_token=5e-5)
        assert ctrl2.decide(StepObservation(1, 0, 1.0,
                                            free_block_frac=None)) == "fp16"

    def test_engine_wires_free_block_frac(self, tiny):
        """A scarce paged pool must engage FP8 through the headroom
        trigger alone (latency thresholds set far out of reach)."""
        cfg, sparams = tiny
        ctrl = DualPrecisionController(
            SLOConfig(tpot_ms=1e9, hysteresis_steps=2,
                      free_block_frac_min=0.3),
            fp16_ms_per_token=1e-9, fp8_ms_per_token=1e-9)
        eng = Engine(cfg, sparams, n_slots=4, capacity=32,
                     controller=ctrl, block_size=4, n_blocks=10)
        for i in range(3):
            eng.submit(Request(f"r{i}", list(range(4 + 8 * i, 12 + 8 * i)),
                               max_new=16))
        eng.run()
        assert "fp8" in ctrl.history, \
            "free-block headroom never engaged FP8"


class TestSimulation:
    def test_dual_beats_fp16_on_bursty_trace(self):
        """Paper Fig 1b: dual matches FP8's SLO compliance while spending
        most time at FP16."""
        reqs = trace.azure_like(duration_s=60, mean_rate=5, seed=3)
        cost = simulate.CostModel(
            fixed_ms=2.0, weight_read_ms_fp16=16.0, weight_read_ms_fp8=8.0,
            kv_ms_per_ktoken=0.001, compute_ms_per_token_fp16=0.06,
            compute_ms_per_token_fp8=0.03)
        r16 = simulate.simulate(reqs, cost, policy="fp16")
        r8 = simulate.simulate(reqs, cost, policy="fp8")
        rd = simulate.simulate(reqs, cost, policy="dual")
        assert r8.slo_violation_s < r16.slo_violation_s
        assert rd.slo_violation_s <= r16.slo_violation_s
        assert rd.fp16_fraction > 0.2, "dual never used fp16"
        assert r16.fp16_fraction == 1.0 and r8.fp16_fraction == 0.0

    def test_trace_burstiness(self):
        reqs = trace.azure_like(duration_s=120, mean_rate=5, seed=0)
        st = trace.rate_stats(reqs, 120)
        assert st["max_rate"] > 2 * st["mean_rate"] * 0.8  # bursty


@pytest.mark.slow
class TestPlanarEngine:
    def test_planar_engine_matches_plain_fp16(self, tiny):
        """NestedKV engine output == plain-cache engine output at fp16."""
        cfg, sparams = tiny
        prompts = [list(range(3, 11)), list(range(30, 38))]
        outs = []
        for planar in (False, True):
            eng = Engine(cfg, sparams, n_slots=4, capacity=64,
                         forced_mode="fp16", kv_planar=planar)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p, max_new=4))
            outs.append({r.request_id: r.output for r in eng.run()})
        assert outs[0] == outs[1]

    def test_planar_engine_fp8_runs(self, tiny):
        cfg, sparams = tiny
        eng = Engine(cfg, sparams, n_slots=2, capacity=64,
                     forced_mode="fp8", kv_planar=True)
        eng.submit(Request("r0", list(range(5, 13)), max_new=4))
        fin = eng.run()
        assert len(fin) == 1 and len(fin[0].output) == 4
