"""Byte-planar KV cache ("NestedKV", beyond-paper extension DESIGN.md §8):
the f16 top byte IS a float8_e5m2 value, so a two-plane cache serves
lossless fp16 reads and half-traffic fp8 reads — the paper's nesting idea
applied to the decode bottleneck our roofline identified."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import nestedfp as nf
from repro.configs import ARCHS
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime


class TestBytePlanes:
    def test_roundtrip_exhaustive_all_f16(self):
        bits = np.arange(1 << 16, dtype=np.uint16).view(np.float16)
        hi, lo = nf.split_bytes(jnp.asarray(bits))
        back = np.asarray(nf.join_bytes(hi, lo))
        np.testing.assert_array_equal(back.view(np.uint16),
                                      bits.view(np.uint16))

    def test_hi_plane_is_exact_e5m2_truncation(self):
        import ml_dtypes
        bits = np.arange(1 << 16, dtype=np.uint16)
        vals = bits.view(np.float16)
        hi, _ = nf.split_bytes(jnp.asarray(vals))
        ours = np.asarray(hi).view(ml_dtypes.float8_e5m2)
        # truncating the top byte == RTZ cast of the f16 value to e5m2
        want = (bits >> 8).astype(np.uint8).view(ml_dtypes.float8_e5m2)
        np.testing.assert_array_equal(ours.view(np.uint8),
                                      want.view(np.uint8))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(-100, 100, width=16, allow_nan=False),
                    min_size=1, max_size=64))
    def test_e5m2_view_error_bounded(self, vals):
        """Truncation error < 1 e5m2 ulp (2^-2 relative)."""
        w = np.asarray(vals, dtype=np.float16)
        hi, _ = nf.split_bytes(jnp.asarray(w))
        approx = np.asarray(nf.e5m2_view(hi))
        wf = np.abs(w.astype(np.float64))
        err = np.abs(approx - w.astype(np.float64))
        assert np.all(err <= np.maximum(wf * 0.25, 2**-16))


@pytest.fixture(scope="module")
def served():
    cfg = ARCHS["qwen3-8b"].reduced()
    params = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


class TestPlanarDecode:
    def test_fp16_planar_bit_identical(self, served):
        cfg, params = served
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        lg, caches, length = M.prefill(rt, params, cfg, {"tokens": toks},
                                       capacity=24)
        t = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        a, _ = M.decode_step(rt, params, cfg, t, caches, jnp.int32(length))
        b, _ = M.decode_step(rt, params, cfg, t, M.planarize_cache(caches),
                             jnp.int32(length))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fp8_planar_close(self, served):
        cfg, params = served
        rt16 = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        rt8 = Runtime(mode="fp8", backend="ref", dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                  cfg.vocab_size)
        lg, caches, length = M.prefill(rt16, params, cfg, {"tokens": toks},
                                       capacity=24)
        t = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        a, _ = M.decode_step(rt16, params, cfg, t, caches, jnp.int32(length))
        c, _ = M.decode_step(rt8, params, cfg, t, M.planarize_cache(caches),
                             jnp.int32(length))
        a, c = np.asarray(a).ravel(), np.asarray(c).ravel()
        cos = a @ c / (np.linalg.norm(a) * np.linalg.norm(c) + 1e-9)
        assert cos > 0.97, cos

    def test_planar_cache_chained_decode(self, served):
        """Multiple planar decode steps stay consistent with f16-cache."""
        cfg, params = served
        rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0,
                                  cfg.vocab_size)
        lg, cf, length = M.prefill(rt, params, cfg, {"tokens": toks},
                                   capacity=24)
        cp = M.planarize_cache(cf)
        t = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for i in range(4):
            a, cf = M.decode_step(rt, params, cfg, t, cf,
                                  jnp.int32(length + i))
            b, cp = M.decode_step(rt, params, cfg, t, cp,
                                  jnp.int32(length + i))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            t = jnp.argmax(a, -1)[:, None].astype(jnp.int32)

    def test_planar_cache_memory_identical(self, served):
        cfg, _ = served
        plain = M.init_cache(cfg, 2, 32)
        planar = M.init_cache(cfg, 2, 32, planar=True)
        nb = lambda t: sum(l.size * l.dtype.itemsize
                           for l in jax.tree_util.tree_leaves(t))
        assert nb(plain) == nb(planar)   # zero memory overhead, like NestedFP
