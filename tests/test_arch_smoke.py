"""Per-architecture smoke tests on REDUCED configs (2 layers, d_model=256,
<=4 experts): one forward/train step + prefill + decode on CPU, asserting
output shapes and no NaNs — required for every assigned architecture.

Also checks the NestedFP serving conversion: fp16-mode decode logits must
match the plain-weight decode logits bit-for-bit in the GEMM inputs
(lossless reconstruction), and fp8 mode must stay finite and close.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# full per-arch sweep (11 archs x jit) — CI runs it in the slow lane
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, ASSIGNED
from repro.models import model as M
from repro.models.convert import to_serving
from repro.models.layers import Runtime

RT_TRAIN = Runtime(mode="train", dtype=jnp.float32)
RT_F16 = Runtime(mode="fp16", dtype=jnp.float32)
RT_F8 = Runtime(mode="fp8", dtype=jnp.float32)

B, S = 2, 32


def _batch(cfg, key, s=S):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, s + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.frontend_len or 8, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[1], (B, M.encdec_enc_len(s), cfg.frontend_dim), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = ARCHS[arch_id].reduced()
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch_id] = (cfg, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_train_step_shapes_and_finite(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: M.train_loss(RT_TRAIN, p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id}: loss NaN/inf"
    assert float(loss) > 0
    assert np.isfinite(float(metrics["acc"]))


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_train_grads_finite(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    grads = jax.jit(jax.grad(
        lambda p, b: M.train_loss(RT_TRAIN, p, cfg, b)[0]))(params, batch)
    flat = jax.tree_util.tree_leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), f"{arch_id}: NaN grad"


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_prefill_then_decode(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    prompt = {k: (v[:, :S] if k == "tokens" else v) for k, v in batch.items()}
    cap = S + 8
    logits, caches, length = M.prefill(RT_TRAIN, params, cfg, prompt,
                                       capacity=cap)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert caches is not None

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg2, caches = M.decode_step(RT_TRAIN, params, cfg, tok, caches,
                                jnp.int32(length))
    assert lg2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg2)))
    # second step exercises cache-threading
    tok2 = jnp.argmax(lg2, -1)[:, None].astype(jnp.int32)
    lg3, _ = M.decode_step(RT_TRAIN, params, cfg, tok2, caches,
                           jnp.int32(length + 1))
    assert np.all(np.isfinite(np.asarray(lg3)))


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "granite-moe-3b-a800m",
                                     "mamba2-2.7b", "deepseek-v3-671b"])
def test_decode_consistency_vs_long_prefill(arch_setup, arch_id):
    """prefill(S) + decode(t) must equal prefill(S+1) last-logits.

    MoE capacity drops depend on the competing token pool (prefill batch
    vs single decode token) — a real property of capacity routing — so the
    consistency check runs drop-free (large capacity_factor)."""
    import dataclasses
    cfg, params = arch_setup(arch_id)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S + 1), 0,
                              cfg.vocab_size)
    lg_a, caches, length = M.prefill(RT_TRAIN, params, cfg,
                                     {"tokens": toks[:, :S]}, capacity=S + 4)
    lg_b, _ = M.decode_step(RT_TRAIN, params, cfg, toks[:, S:S + 1], caches,
                            jnp.int32(length))
    lg_full, _, _ = M.prefill(RT_TRAIN, params, cfg, {"tokens": toks},
                              capacity=S + 4)
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_full),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "gemma3-1b", "zamba2-2.7b"])
def test_serving_fp16_matches_plain_and_fp8_close(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    lg_plain, _, _ = M.prefill(RT_TRAIN, params, cfg, {"tokens": toks},
                               capacity=S)
    sparams = to_serving(params)
    lg_f16, _, _ = M.prefill(RT_F16, sparams, cfg, {"tokens": toks},
                             capacity=S)
    # fp16 path: weights reconstruct losslessly; activation dtype identical
    np.testing.assert_allclose(np.asarray(lg_f16), np.asarray(lg_plain),
                               rtol=5e-3, atol=5e-3)
    lg_f8, _, _ = M.prefill(RT_F8, sparams, cfg, {"tokens": toks}, capacity=S)
    assert np.all(np.isfinite(np.asarray(lg_f8)))
    # fp8 is lossy but must stay correlated with the f16 logits
    a, b = np.asarray(lg_f8).ravel(), np.asarray(lg_f16).ravel()
    cos = np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9)
    assert cos > 0.98, f"{arch_id}: fp8 diverged (cos={cos:.4f})"


def test_moe_drop_fraction_reported(arch_setup):
    cfg, params = arch_setup("granite-moe-3b-a800m")
    batch = _batch(cfg, jax.random.PRNGKey(6))
    _, metrics = jax.jit(
        lambda p, b: M.train_loss(RT_TRAIN, p, cfg, b))(params, batch)
    assert 0.0 <= float(metrics["moe_drop_fraction"]) <= 1.0
