"""Power-of-two per-channel scaling (beyond-paper, DESIGN.md §8):
losslessness must survive the rescaling, applicability must widen, and
FP8 resolution must improve for small-magnitude channels."""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import nestedfp as nf
from repro.core import quant

RNG = np.random.RandomState(11)


class TestPow2Losslessness:
    def test_roundtrip_bit_exact_mixed_magnitudes(self):
        """Columns spanning 1e-3 .. 2.9 absmax — including channels the
        paper would mark as exceptions (absmax > 1.75)."""
        cols = []
        for scale in (1e-3, 0.02, 0.4, 1.6, 2.9):
            cols.append(RNG.uniform(-scale, scale, (128, 4)))
        w = jnp.asarray(np.concatenate(cols, 1).astype(np.float16))
        assert not bool(nf.is_applicable(w))          # paper: exception
        assert bool(nf.is_applicable_pow2(w))         # pow2: applicable
        u, l, k = nf.encode_pow2(w)
        back = nf.decode_pow2(u, l, k)
        np.testing.assert_array_equal(
            np.asarray(back).view(np.uint16), np.asarray(w).view(np.uint16))

    @settings(max_examples=60, deadline=None)
    @given(st.floats(1e-4, 8.0), st.integers(0, 2**31 - 1))
    def test_roundtrip_random_channel_scales(self, scale, seed):
        """Bit-exact roundtrip whenever the pow2 applicability predicate
        accepts the tensor (the NestedTensor contract)."""
        from hypothesis import assume
        r = np.random.RandomState(seed % (2**31))
        w = jnp.asarray((r.standard_normal((64, 8)) * scale)
                        .astype(np.float16))
        assume(bool(nf.is_applicable_pow2(w)))
        u, l, k = nf.encode_pow2(w)
        back = nf.decode_pow2(u, l, k)
        np.testing.assert_array_equal(
            np.asarray(back).view(np.uint16), np.asarray(w).view(np.uint16))

    def test_subnormal_channels_fall_back_to_k0(self):
        """A channel mixing subnormals with >1.75 values cannot shift
        losslessly; k must be 0 there (and the tensor stays exception)."""
        col = np.zeros((64, 1), np.float16)
        col[0, 0] = np.float16(2.5)
        col[1, 0] = np.float16(2 ** -24)       # smallest subnormal
        w = jnp.asarray(col)
        u, l, k = nf.encode_pow2(w)
        assert int(np.asarray(k)[0]) == 0
        # fixed-scale path still reconstructs whatever was encodable
        assert not bool(nf.is_applicable_pow2(w))


class TestPow2FP8Accuracy:
    def test_normal_range_channels_gain_nothing(self):
        """KEY INSIGHT (explains the paper's Table 2): floating-point
        quantization is scale-invariant over NORMAL values, so per-channel
        rescaling cannot beat the single global 2^8 scale unless values
        land in the e4m3 subnormal band (|w| < 2^-14). This is exactly why
        the paper's global scale matches per-channel absmax accuracy."""
        w = jnp.asarray((RNG.standard_normal((512, 64)) * 0.002)
                        .astype(np.float16))
        u, _ = nf.encode(w)
        m_g = quant.quant_error_metrics(w, nf.fp8_dequant(u))
        u2, _, k = nf.encode_pow2(w)
        w_pow2 = (nf.fp8_view(u2).astype(jnp.float32)
                  * nf.fp8_dequant_scale_pow2(k))
        m_p = quant.quant_error_metrics(w, w_pow2)
        assert abs(m_p["sqnr_db"] - m_g["sqnr_db"]) < 0.5, (m_g, m_p)

    def test_subnormal_band_channels_gain_resolution(self):
        """|w| ~ 2^-16: global scale lands in the e4m3 subnormal band
        (huge relative error); pow2 shifts them back to normals."""
        w = jnp.asarray((RNG.standard_normal((512, 64)) * 2.0**-16)
                        .astype(np.float16))
        # paper-faithful global scale
        u, _ = nf.encode(w)
        w_global = nf.fp8_dequant(u)
        m_g = quant.quant_error_metrics(w, w_global)
        # pow2 per-channel
        u2, _, k = nf.encode_pow2(w)
        w_pow2 = (nf.fp8_view(u2).astype(jnp.float32)
                  * nf.fp8_dequant_scale_pow2(k))
        m_p = quant.quant_error_metrics(w, w_pow2)
        assert m_p["sqnr_db"] > m_g["sqnr_db"] + 5, (m_g, m_p)

    def test_matches_global_when_already_full_range(self):
        w = jnp.asarray(RNG.uniform(-1.7, 1.7, (256, 32)).astype(np.float16))
        _, _, k = nf.encode_pow2(w)
        assert np.all(np.asarray(k) == 0)      # no shift needed
