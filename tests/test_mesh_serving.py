"""Tensor-parallel paged serving: the 4-device CPU mesh must reproduce
single-device greedy serving BIT-EXACTLY through the whole engine
lifecycle — plain decode in both forced modes, recompute preemption,
COW prefix forking, gemma3 sliding-window reclaim, and the shard_map
Pallas decode backend — with the one-dispatch accounting invariant
(`stats` counts logical steps, not shards) held throughout.

The `TestMeshParity` cases need `jax.device_count() >= 4`: they run
for real in the CI `mesh` lane (XLA_FLAGS forces 4 host devices before
jax imports) and are skipped in a stock single-device session. The
slow `test_suite_under_forced_device_count` subprocess re-runs this
module with the flag set, so the default tier-1 slow lane still covers
everything here on one physical machine.
"""

import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core.policy import SpeculationConfig
from repro.models import model as M
from repro.models.convert import to_serving
from repro.serving.engine import Engine, Request

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(CI mesh lane / the slow subprocess test below)")


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()      # 4 q / 4 kv heads: divisible
    return cfg, to_serving(M.init_params(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_serving_mesh
    if jax.device_count() < 4:
        return None
    return make_serving_mesh(4)


def _serve(cfg, sparams, mesh, requests, **kw):
    eng = Engine(cfg, sparams, mesh=mesh, **kw)
    for r in requests:
        eng.submit(r)
    eng.run()
    return {r.request_id: r.output for r in eng.finished}, eng


RNG = np.random.RandomState(11)
PROMPTS = [list(RNG.randint(1, 200, n)) for n in (13, 29, 7, 21)]


def _reqs(max_new=6):
    return [Request(f"r{i}", list(p), max_new=max_new)
            for i, p in enumerate(PROMPTS)]


@needs_mesh
class TestMeshParity:
    def test_greedy_decode_bit_exact_fp16_and_fp8(self, tiny, mesh):
        """The ROADMAP acceptance: 1-chip == 4-chip greedy outputs for a
        planar GQA config in BOTH forced modes, and the dispatch/h2d
        stats count logical steps (mesh-size-invariant)."""
        cfg, sp = tiny
        for mode in ("fp16", "fp8"):
            kw = dict(n_slots=8, capacity=64, forced_mode=mode,
                      kv_planar=True, prefix_cache=False)
            ref, eref = _serve(cfg, sp, None, _reqs(), **kw)
            got, egot = _serve(cfg, sp, mesh, _reqs(), **kw)
            assert got == ref, mode
            assert egot.stats == eref.stats, (eref.stats, egot.stats)

    def test_speculative_decode_bit_exact_under_mesh(self, tiny, mesh):
        """N-gram speculation on the 4-chip mesh: accepted-prefix
        selection and rollback read host state only, so the mesh run
        must emit the same tokens as a plain (non-speculative)
        single-device run — and actually accept drafts while doing it."""
        cfg, sp = tiny
        rep = [5, 6, 7, 8] * 6
        prompts = [rep, list(range(3, 11))]
        reqs = lambda: [Request(f"s{i}", list(p), max_new=8)
                        for i, p in enumerate(prompts)]
        for mode in ("fp16", "fp8"):
            kw = dict(n_slots=4, capacity=96, forced_mode=mode,
                      kv_planar=True, prefix_cache=False)
            ref, _ = _serve(cfg, sp, None, reqs(), **kw)
            got, egot = _serve(cfg, sp, mesh, reqs(),
                               speculate=SpeculationConfig(ngram_min=1), **kw)
            assert got == ref, mode
            st = egot.spec_stats()
            assert st["accepted"] > 0, st
            assert st["tokens_accepted_per_dispatch"] > 1.0, st

    def test_prefill_stays_one_dispatch_under_mesh(self, tiny, mesh):
        """`prefill_dispatches_per_step == 1` survives sharding: a step
        planning N concurrent prompt chunks is still ONE pjit call."""
        cfg, sp = tiny
        eng = Engine(cfg, sp, n_slots=8, capacity=64, forced_mode="fp16",
                     chunk_tokens=512, prefix_cache=False, mesh=mesh)
        for r in _reqs(max_new=2):
            eng.submit(r)
        eng.step()
        assert eng.stats["chunks"] == len(PROMPTS)
        assert eng.stats["prefill_dispatches"] == 1, eng.stats
        assert eng.stats["decode_dispatches"] == 1, eng.stats

    def test_preempt_and_requeue_bit_exact(self, tiny, mesh):
        """Scarce pool: decode growth preempts the youngest sequence and
        recompute-continues it — identical schedule and outputs on the
        mesh (the preemption decision reads host state only)."""
        cfg, sp = tiny
        kw = dict(n_slots=8, capacity=96, forced_mode="fp16",
                  kv_planar=True, block_size=16, n_blocks=8,
                  prefix_cache=False)
        long = [list(np.random.RandomState(3).randint(1, 200, n))
                for n in (24, 18, 30, 11)]
        reqs = lambda: [Request(f"p{i}", list(p), max_new=10)
                        for i, p in enumerate(long)]
        ref, eref = _serve(cfg, sp, None, reqs(), **kw)
        got, egot = _serve(cfg, sp, mesh, reqs(), **kw)
        assert egot.stats["preemptions"] > 0, egot.stats
        assert got == ref
        assert egot.stats == eref.stats

    def test_cow_prefix_fork_bit_exact(self, tiny, mesh):
        """Prefix-cache hit + COW fork of the shared tail block: the
        jitted per-group block copy runs on the sharded pool."""
        cfg, sp = tiny
        shared = list(range(40, 72))             # two full 16-token blocks

        def serve(m):
            eng = Engine(cfg, sp, n_slots=8, capacity=96,
                         forced_mode="fp8", kv_planar=True, block_size=16,
                         prefix_cache=True, mesh=m)
            eng.submit(Request("seed", shared + [7], max_new=4))
            eng.run()
            for i in range(2):
                # prompts == the cached full-block prefix: prefill
                # resumes INSIDE the shared tail block, forcing the fork
                eng.submit(Request(f"fork{i}", list(shared), max_new=6))
            eng.run()
            return {r.request_id: r.output for r in eng.finished}, eng

        ref, eref = serve(None)
        got, egot = serve(mesh)
        ps = egot.prefix_cache_stats()
        assert ps["hit_rate"] > 0 and ps["cow_forks"] > 0, ps
        assert got == ref
        assert egot.stats == eref.stats
        assert egot.prefix_cache_stats() == eref.prefix_cache_stats()

    def test_gemma3_window_reclaim_bit_exact(self, mesh):
        """Sliding-window serving with 1 kv head: the K/V projections and
        the paged pool take the REPLICATION fallback (1 % 4 != 0) while q
        heads stay sharded; window slides must still free local blocks
        and match single-device outputs exactly."""
        cfg = ARCHS["gemma3-1b"].reduced()
        sp = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
        long = list(np.random.RandomState(7).randint(1, 200, 96))
        kw = dict(n_slots=4, capacity=128, forced_mode="fp16",
                  block_size=16)
        ref, eref = _serve(cfg, sp, None, [Request("w", long, max_new=8)],
                           **kw)
        got, egot = _serve(cfg, sp, mesh, [Request("w", long, max_new=8)],
                           **kw)
        assert egot.stats["window_reclaimed_blocks"] > 0, egot.stats
        assert got == ref
        assert egot.stats == eref.stats

    def test_pallas_decode_shard_map_bit_exact(self, tiny, mesh):
        """attn_backend='pallas' under the mesh: the decode kernel runs
        inside shard_map on per-shard head slices (4 kv heads / 4
        shards) and must agree with the single-device kernel run."""
        cfg, sp = tiny
        kw = dict(n_slots=2, capacity=64, forced_mode="fp8",
                  kv_planar=True, attn_backend="pallas",
                  prefix_cache=False)
        req = lambda: [Request("p", list(range(5, 18)), max_new=3)]
        ref, _ = _serve(cfg, sp, None, req(), **kw)
        got, _ = _serve(cfg, sp, mesh, req(), **kw)
        assert got == ref

    def test_mla_latent_replication_bit_exact(self, mesh):
        """MLA descriptor: latent planes replicate (no head axis), the
        absorbed attention shards over q heads — outputs exact."""
        cfg = ARCHS["deepseek-v3-671b"].reduced()
        sp = to_serving(M.init_params(jax.random.PRNGKey(0), cfg))
        reqs = lambda: [Request(f"m{i}",
                                list(np.random.RandomState(i)
                                     .randint(1, 200, 12)), max_new=4)
                        for i in range(2)]
        kw = dict(n_slots=4, capacity=64, forced_mode="fp16",
                  block_size=16)
        ref, eref = _serve(cfg, sp, None, reqs(), **kw)
        got, egot = _serve(cfg, sp, mesh, reqs(), **kw)
        assert got == ref
        assert egot.stats == eref.stats

    def test_table_mirror_stays_incremental_under_mesh(self, tiny, mesh):
        """The replicated device-table mirror keeps the incremental-
        scatter discipline: steady-state decode ships zero or O(dirty)
        table bytes per step — never a full re-upload per shard."""
        cfg, sp = tiny
        eng = Engine(cfg, sp, n_slots=4, capacity=64, forced_mode="fp16",
                     prefix_cache=False, mesh=mesh)
        eng.submit(Request("r", list(range(5, 20)), max_new=20))
        eng.step()                          # prefill + first decode
        full = eng.blocks.group_tables().nbytes
        b0 = eng.blocks.table_h2d_bytes
        eng.step()                          # len 16 -> 17: one new block
        grew = eng.blocks.table_h2d_bytes - b0
        assert 0 < grew < full, (grew, full)
        b1 = eng.blocks.table_h2d_bytes
        for _ in range(3):                  # decode inside block 2
            eng.step()
        assert eng.blocks.table_h2d_bytes == b1


@pytest.mark.slow
def test_suite_under_forced_device_count(tmp_path):
    """Re-run this module with 4 forced host devices so the mesh parity
    suite executes even when the outer session is single-device (the
    default tier-1 slow lane)."""
    if jax.device_count() >= 4:
        pytest.skip("already running with >= 4 devices")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-m", "not slow"],
        capture_output=True, text=True, timeout=1500, env=env,
        cwd=os.getcwd())
    assert r.returncode == 0, r.stdout + r.stderr
    assert "skipped" not in r.stdout.split("passed")[0] or \
        "deselected" in r.stdout, r.stdout
