"""Hypothesis property tests on system-level invariants (beyond the
format-level exhaustive tests): engine/slot accounting, simulator
conservation laws, quantizer bounds, trace determinism."""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import nestedfp as nf
from repro.core import quant
from repro.core.policy import DualPrecisionController, SLOConfig, StepObservation
from repro.serving import simulate, trace
from repro.serving.kvcache import SlotManager


class TestSlotManagerInvariants:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                              st.integers(0, 7)), min_size=1, max_size=60))
    def test_never_double_allocates_or_leaks(self, ops):
        sm = SlotManager(4, 128)
        live: dict[int, str] = {}
        counter = 0
        for op, arg in ops:
            if op == "alloc":
                idx = sm.try_allocate(f"r{counter}", 8, 4)
                counter += 1
                if idx is not None:
                    assert idx not in live, "double allocation"
                    live[idx] = sm.slots[idx].request_id
            else:
                if live:
                    idx = sorted(live)[arg % len(live)]
                    sm.release(idx)
                    del live[idx]
            assert sm.n_free() == sm.n_slots - len(live)
            assert set(sm.active()) == set(live)


class TestSimulatorInvariants:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000), st.floats(1.0, 12.0))
    def test_all_requests_finish_and_time_monotone(self, seed, rate):
        reqs = trace.azure_like(duration_s=20, mean_rate=rate, seed=seed,
                                prompt_len=64, max_new=32)
        cost = simulate.CostModel()
        for pol in ("fp16", "fp8", "dual"):
            r = simulate.simulate(reqs, cost, policy=pol)
            assert r.n_finished == len(reqs), (pol, r.n_finished, len(reqs))
            assert r.duration_s >= 0
            assert 0.0 <= r.fp16_fraction <= 1.0

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000))
    def test_fp8_never_slower_than_fp16(self, seed):
        reqs = trace.azure_like(duration_s=15, mean_rate=6, seed=seed)
        cost = simulate.CostModel()
        r16 = simulate.simulate(reqs, cost, policy="fp16")
        r8 = simulate.simulate(reqs, cost, policy="fp8")
        assert r8.duration_s <= r16.duration_s + 1e-6


class TestControllerInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, 2000), min_size=1, max_size=100))
    def test_mode_always_valid_and_dwell_respected(self, loads):
        ctrl = DualPrecisionController(
            SLOConfig(hysteresis_steps=4),
            fp16_ms_per_token=0.5, fp8_ms_per_token=0.25)
        fp8_run = 0
        for tokens in loads:
            m = ctrl.decide(StepObservation(tokens, 0, None))
            assert m in ("fp16", "fp8")
            if m == "fp8":
                fp8_run += 1
            else:
                # must have dwelt at least hysteresis steps in fp8 (or
                # never entered)
                assert fp8_run == 0 or fp8_run >= ctrl.slo.hysteresis_steps
                fp8_run = 0


class TestQuantInvariants:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                    min_size=4, max_size=128))
    def test_act_quant_range_and_dequant_bound(self, vals):
        x = jnp.asarray(np.asarray(vals, np.float32).reshape(1, -1))
        q, s = quant.quantize_act_per_tensor(x)
        qf = np.asarray(q, dtype=np.float32)
        assert np.abs(qf).max() <= nf.E4M3_MAX
        deq = qf * float(s)
        amax = float(np.abs(np.asarray(x)).max())
        # e4m3 relative error bound on the dequantized tensor
        assert np.abs(deq - np.asarray(x)).max() <= max(amax / 8.0, 1e-6)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_per_token_scales_isolate_rows(self, seed):
        r = np.random.RandomState(seed % (2**31))
        x = np.ones((4, 32), np.float32)
        x[0] *= r.uniform(100, 1000)          # one huge row
        q, s = quant.quantize_act_per_token(jnp.asarray(x))
        deq = np.asarray(q, np.float32) * np.asarray(s)
        # small rows must not be crushed by the big row's scale
        assert np.abs(deq[1:] - x[1:]).max() < 0.1


class TestTraceInvariants:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000))
    def test_deterministic_and_sorted(self, seed):
        a = trace.azure_like(duration_s=10, seed=seed)
        b = trace.azure_like(duration_s=10, seed=seed)
        assert [(r.arrival_s, r.prompt_len) for r in a] == \
               [(r.arrival_s, r.prompt_len) for r in b]
        times = [r.arrival_s for r in a]
        assert times == sorted(times)


def _descriptor(kind: str):
    """Real per-family cache descriptors (kvcache.py CacheDescriptor)
    derived from assigned archs: gqa (qwen), mla (deepseek-v3 latents),
    hybrid (zamba2 shared-attn + slot-resident SSM state), swa (gemma3
    sliding-window layer groups)."""
    from repro.configs import ARCHS
    from repro.models.model import cache_descriptor

    arch = {"gqa": "qwen1.5-0.5b", "mla": "deepseek-v3-671b",
            "hybrid": "zamba2-2.7b", "swa": "gemma3-1b"}[kind]
    desc = cache_descriptor(ARCHS[arch].reduced())
    assert desc.kind == ("gqa" if kind == "swa" else kind)
    if kind == "swa":
        # one global + one windowed local group, window odd (never
        # block-aligned)
        assert desc.group_windows == (None, 19)
    else:
        assert desc.group_windows == (None,)
    return desc


class _SoupEngine:
    """Minimal engine double for the router op soup: one token per
    active request per step, honest drain, no KV."""

    def __init__(self):
        self.queue, self.active, self.prefilling = [], {}, []
        self.finished = []
        self.stats = {"decode_tokens": 0, "chunk_tokens": 0}
        self.forced_mode, self.restore_policy = "fp16", None
        self.fault_hook = None
        self.last_mode, self.last_stall_ms, self.inject_stall_ms = \
            "fp16", 0.0, 0.0
        self.blocks = None

    def submit(self, req):
        self.queue.append(req)

    def step(self):
        if self.fault_hook is not None:
            self.fault_hook(self)
        while self.queue:
            r = self.queue.pop(0)
            self.active[r.request_id] = r
        for r in list(self.active.values()):
            r.output.append(len(r.output))
            self.stats["decode_tokens"] += 1
            if len(r.output) >= r.max_new:
                del self.active[r.request_id]
                self.finished.append(r)
        self.last_mode = self.forced_mode or "fp16"
        self.last_stall_ms, self.inject_stall_ms = self.inject_stall_ms, 0.0

    def drain_requests(self):
        out = list(self.active.values()) + self.queue
        self.active.clear()
        self.queue.clear()
        return out


class TestRouterConservation:
    """Hypothesis op soup over the multi-replica router: submits, kills,
    revives, injected step raises, and steps interleave in any order,
    and every submitted request must be EXACTLY-ONCE accounted — retired
    (completed), explicitly shed, or still in flight (including orphans
    parked through a zero-survivor window) — never lost, never
    duplicated. `Router.stats()["lost"]` must read zero at every
    observation point, not just at the end."""

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_replicas=st.integers(1, 3),
           ops=st.lists(st.tuples(st.sampled_from(
               ["submit", "kill", "revive", "raise", "step"]),
               st.integers(0, 5)), min_size=5, max_size=80))
    def test_exactly_once_accounting(self, seed, n_replicas, ops):
        from repro.core.policy import DegradePolicy, RestorePolicy
        from repro.serving.engine import Request
        from repro.serving.faults import FaultEvent, FaultPlan
        from repro.serving.router import Router

        # pass 1: ops -> the fault plan the router will replay (a
        # kill/revive/raise between step k-1 and k fires at step k)
        events, step = [], 0
        for op, arg in ops:
            if op == "step":
                step += 1
            elif op in ("kill", "revive", "raise"):
                events.append(FaultEvent(step, arg % n_replicas, op))
        engines = [_SoupEngine() for _ in range(n_replicas)]
        for e in engines:
            e.restore_policy = RestorePolicy()
        router = Router(engines, plan=FaultPlan(events),
                        factories=[_SoupEngine] * n_replicas,
                        policy=DegradePolicy(shed_budget_tokens=64,
                                             hysteresis_steps=3),
                        dead_after_errors=2)
        rng = np.random.RandomState(seed % (2**31))
        submitted: list[str] = []

        def audit():
            st_ = router.stats()
            assert st_["lost"] == 0, st_
            seen = [q.request_id for q in router.finished] \
                + [q.request_id for q in router.shed_requests] \
                + [rid for live in router._live.values() for rid in live] \
                + [q.request_id for q in router._orphans]
            assert sorted(seen) == sorted(submitted), \
                "request leaked or duplicated"

        # pass 2: replay the same ops against the router
        for i, (op, arg) in enumerate(ops):
            if op == "submit":
                req = Request(f"q{i}", rng.randint(1, 999, size=1 + arg)
                              .tolist(), int(rng.randint(1, 6)))
                try:
                    router.submit(req)
                    submitted.append(req.request_id)
                except RuntimeError:
                    pass                 # zero serving replicas: rejected
            elif op == "step":
                router.step()
                audit()
        audit()
        if any(r.serving for r in router.replicas):
            router.run(max_steps=500, allow_partial=True)
            audit()


class TestBlockManagerCOWInvariants:
    """Hypothesis-driven op soup over the refcounted prefix-caching
    BlockManager, parametrized over the per-family cache DESCRIPTORS
    (GQA K/V planes, MLA latent planes, hybrid shared-attn planes +
    slot-resident SSM state): refcounts never negative, zero-ref blocks
    live on exactly one of {free list, LRU cache}, shared blocks never
    on either, COW forks are atomic, the hash index stays bijective,
    and the incremental table array never goes stale (check_invariants
    audits all of it). Recurrent descriptors run with the prefix cache
    off — exactly as the engine instantiates them. The swa (gemma3)
    descriptor additionally mixes window SLIDE-FREES into the soup:
    refcounts and the free list stay conserved, no block is ever both
    free and in a live table, and a slide-freed block never reappears
    through `lookup_prefix`/`_match_plan` for the local group (it is
    evicted from the index the moment its last holder slides past).
    `truncate` (the speculative-decoding rollback) joins the soup as its
    own op: dropped blocks are conserved through the normal release
    machinery (shared blocks survive for their other holders), the
    committed-hash chain never extends past the cut, slid holes stay
    holes, and the device table mirror keeps matching the host tables —
    check_invariants audits all of it after every op.

    The tiered-KV `spill`/`restore` ops drive the host tier in the same
    bookkeeping form the engine uses (take_spills -> store_spill,
    restore_jobs -> claim/finish, lazy lo drains): spilled entries are
    content-tagged by their chain hash and must read back byte-identical
    at restore (restored bytes == spilled bytes, host entries never
    aliased or clobbered by allocator reuse of the evicted block id);
    attach with allow_host exercises host-hit re-admission, and ops that
    would WRITE a row's blocks honor the engine's row_unrestored gate.
    check_invariants additionally audits tier conservation: spill queue
    <-> pending set, exact host-entry pin accounting, exact host byte
    totals, and lo-pending entries staying hosted + pinned."""

    @pytest.mark.parametrize("kind", ["gqa", "mla", "hybrid", "swa"])
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           ops=st.lists(st.integers(0, 8), min_size=10, max_size=120))
    def test_op_soup(self, kind, seed, ops):
        from repro.serving.kvcache import BlockManager, HostPool, SlotManager

        desc = _descriptor(kind)
        assert (desc.bytes_per_token > 0) == bool(desc.planes)
        assert (desc.bytes_per_slot > 0) == bool(desc.slot_planes)
        rng = np.random.RandomState(seed % (2**31))
        bm = BlockManager(n_slots=3, block_size=4,
                          n_blocks=10, max_blocks_per_seq=8,
                          prefix_cache=desc.prefix_cacheable,
                          group_windows=desc.group_windows,
                          host_pool=HostPool()
                          if desc.prefix_cacheable else None)

        def tag(h):
            # deterministic content tag: a restore must read back the
            # exact bytes its spill deposited
            return np.full((3, 4), h & 0xFF, np.uint8)

        def capture_spills():
            for g, b, h in bm.take_spills():
                bm.store_spill(g, h, {"p": tag(h)})
        # slot-resident state side claimed/released in lockstep
        sm = SlotManager(3, 32) if desc.slot_planes else None
        # streams longer than the swa window (19) so slides actually fire
        streams = [list(range(s, s + 28)) for s in (0, 0, 32)]
        live: list[int] = []
        for op in ops:
            if op == 0 and bm.n_free_slots():
                toks = streams[rng.randint(len(streams))]
                idx = bm.try_allocate(f"r{rng.randint(1 << 30)}", len(toks),
                                      4, bm.prefix_admit_discount(toks))
                if idx is not None:
                    matched = bm.attach_prefix(
                        idx, toks, allow_host=bool(rng.randint(2)))
                    assert desc.prefix_cacheable or matched == 0, \
                        "recurrent descriptor shared a prefix"
                    if sm is not None:
                        sm.claim(idx, f"r{idx}", len(toks), 4)
                    live.append(idx)
            elif op == 1 and live:
                idx = live[rng.randint(len(live))]
                # engine contract: rows holding unrestored blocks are
                # gated out of chunk scheduling, so they never write
                if bm.row_unrestored(idx):
                    continue
                toks = streams[rng.randint(len(streams))]
                n = rng.randint(1, len(toks) + 1)
                if bm.ensure(idx, max(n, bm.seqs[idx].length)) \
                        and n >= bm.seqs[idx].length \
                        and bm.cow_for_write(idx, rng.randint(n), n) \
                        is not None:
                    bm.commit(idx, n, toks)
            elif op == 2 and live:
                idx = live.pop(rng.randint(len(live)))
                bm.release(idx)
                if sm is not None:
                    sm.release(idx)
            elif op == 3:
                bm.lookup_prefix(streams[rng.randint(len(streams))])
            elif op == 4 and live:
                # explicit window slide: capture what it frees and prove
                # none of it can ever be prefix-matched again
                idx = live[rng.randint(len(live))]
                before = [set(f) for f in bm._free]
                bm.slide_window(idx)
                slid_freed = {(g, b) for g, f in enumerate(bm._free)
                              for b in set(f) - before[g]}
                assert all(gb not in bm._hash_of for gb in slid_freed), \
                    "slide-freed block still registered"
                for toks in streams:
                    _, plan, _ = bm._match_plan(toks)
                    matched = {(g, b) for g, (_, blks) in enumerate(plan)
                               for b in blks}
                    assert not (matched & slid_freed), \
                        "slide-freed block reappeared via prefix match"
            elif op == 5 and live:
                # speculative-rollback truncate to a random cut point:
                # blocks must be conserved (freed/LRU-parked/kept-shared,
                # never leaked — check_invariants recounts them), the
                # hash chain must not outlive the cut, and slid holes
                # must stay holes (also audited below)
                idx = live[rng.randint(len(live))]
                n = int(rng.randint(0, 33))
                zero_ref_before = sum(map(len, bm._free)) \
                    + sum(map(len, bm._lru))
                dropped = bm.truncate(idx, n)
                assert dropped >= 0
                # releasing can only grow the zero-ref population (a
                # shared drop decrefs without freeing)
                assert sum(map(len, bm._free)) + sum(map(len, bm._lru)) \
                    >= zero_ref_before
                seq = bm.seqs[idx]
                assert seq.length <= n
                for g in seq.groups:
                    assert len(g.blocks) <= -(-n // bm.block_size)
                    assert len(g.hashes) <= n // bm.block_size
                    assert g.slid <= len(g.blocks)
            elif op == 6 and bm.host is not None:
                # engine spill-capture contract: drain the queue and
                # deposit content-tagged bytes for each evicted block
                before = bm.host.bytes
                queued = len(bm._spill_queue)
                capture_spills()
                assert not bm._spill_queue and not bm._spill_pending
                # inclusive tier: every captured block adds its bytes
                # unless its hash was already hosted
                assert bm.host.bytes >= before
                assert len(bm.host) <= bm.host.stats["spilled_blocks"] \
                    + bm.host.stats["loaded_blocks"], (queued, bm.host.stats)
            elif op == 7 and bm.host is not None and bm.restore_jobs:
                # engine restore-drain contract: capture first (a job may
                # target a spill-pending entry), then claim + finish;
                # restored bytes must equal the spilled bytes, unclobbered
                # by any allocator reuse of the evicted block id
                capture_spills()
                while bm.restore_jobs:
                    g, b, h, t = bm.restore_jobs.popleft()
                    if not bm.claim_restore(g, b, h, t):
                        continue             # voided by release/preempt
                    entry = bm.host.get((g, h))
                    assert (entry["p"] == tag(h)).all(), \
                        "host entry aliased or clobbered"
                    bm.finish_restore(g, b, h,
                                      lo_pending=bool(rng.randint(2)))
            elif op == 8 and bm.host is not None:
                # lazy lo-plane drain: pins transfer to the uploader and
                # are released once the bytes land
                for g, b, h in bm.take_lo_pending():
                    assert (g, h) in bm.host and bm.host.pinned((g, h))
                    assert (bm.host.get((g, h))["p"] == tag(h)).all()
                    bm.host.unpin((g, h))
            bm.check_invariants()
            if sm is not None:
                assert set(sm.active()) == set(live), \
                    "slot-state side fell out of lockstep"
        for idx in live:
            bm.release(idx)
            if sm is not None:
                sm.release(idx)
        bm.check_invariants()
        assert bm.blocks_in_use() == 0
        assert bm.n_free_blocks() == bm.n_blocks
