"""Fault-tolerant multi-replica router (`serving/router.py`) +
deterministic fault injection (`serving/faults.py`).

Two tiers of coverage:

* **Stub-engine tests** exercise the router's own machinery — health
  state transitions, rendezvous placement, degrade policy application,
  drain-failure rebuild, conservation accounting, virtual-clock cost
  modeling — with a minimal engine double, so they run in milliseconds
  and can sweep many schedules.
* **Real-engine tests** prove the paper-level guarantees end to end:
  a seeded replica kill mid-generation completes every in-flight
  request BIT-IDENTICAL to a no-fault run (the engine's recompute
  replay invariant carried across replicas), and a corrupted host-tier
  spill is caught by its blake2b checksum and recomputed — counted,
  never a crash, never a wrong token.
"""

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core.policy import DegradePolicy, RestorePolicy
from repro.models import model as M
from repro.models.convert import to_serving
from repro.serving import trace
from repro.serving.engine import Engine, Request
from repro.serving.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  InjectedFault)
from repro.serving.router import (DEAD, DEGRADED, HEALTHY, RECOVERING,
                                  Router, StepCostModel, VirtualClock)


# =============================================================================
# stub engine: the minimal surface the router drives
# =============================================================================

class StubEngine:
    """One emitted token per active request per step; no KV, no jax."""

    def __init__(self):
        self.queue: list[Request] = []
        self.active: dict[str, Request] = {}
        self.prefilling: list = []
        self.finished: list[Request] = []
        self.stats = {"decode_tokens": 0, "chunk_tokens": 0}
        self.forced_mode = "fp16"
        self.restore_policy = RestorePolicy()
        self.fault_hook = None
        self.last_mode = "fp16"
        self.last_stall_ms = 0.0
        self.inject_stall_ms = 0.0
        self.blocks = None               # no KV tier: failover recomputes

    def submit(self, req: Request) -> None:
        if not req.tokens:
            raise ValueError("empty prompt")
        self.queue.append(req)

    def step(self) -> None:
        if self.fault_hook is not None:
            self.fault_hook(self)        # containment point, like Engine
        while self.queue:
            r = self.queue.pop(0)
            self.active[r.request_id] = r
        for r in list(self.active.values()):
            r.output.append(len(r.output))
            self.stats["decode_tokens"] += 1
            if len(r.output) >= r.max_new:
                del self.active[r.request_id]
                self.finished.append(r)
        self.last_mode = self.forced_mode or "fp16"
        self.last_stall_ms, self.inject_stall_ms = self.inject_stall_ms, 0.0

    def drain_requests(self) -> list[Request]:
        out = list(self.active.values()) + self.queue
        self.active.clear()
        self.queue.clear()
        return out


class BrokenDrainEngine(StubEngine):
    """Drain raises too — forces the registry-recovery + rebuild path."""

    def drain_requests(self):
        raise RuntimeError("engine state is toast")


def _req(rid, toks, max_new=4):
    return Request(str(rid), list(toks), max_new)


def _stub_router(n=2, **kw):
    return Router([StubEngine() for _ in range(n)], **kw)


# =============================================================================
# health state machine
# =============================================================================

class TestHealthStates:
    def test_raise_degrades_then_consecutive_raises_kill(self):
        plan = FaultPlan([FaultEvent(0, 0, "raise"),
                          FaultEvent(1, 0, "raise")])
        r = _stub_router(1, plan=plan, dead_after_errors=2)
        r.submit(_req("a", [1, 2, 3]))
        r.step()                         # raise #1: degraded, self-requeued
        assert r.replicas[0].state == DEGRADED
        assert r.stats()["lost"] == 0
        r.step()                         # raise #2: dead, work orphaned
        assert r.replicas[0].state == DEAD
        st = r.stats()
        assert st["step_errors"] == 2 and st["lost"] == 0
        assert st["in_flight"] == 1      # orphaned, not lost

    def test_success_resets_error_count(self):
        plan = FaultPlan([FaultEvent(0, 0, "raise"),
                          FaultEvent(2, 0, "raise")])
        r = _stub_router(1, plan=plan, dead_after_errors=2, heal_steps=50)
        r.submit(_req("a", [1, 2, 3], max_new=16))
        for _ in range(4):
            r.step()
        # non-consecutive raises never reach the dead threshold
        assert r.replicas[0].state == DEGRADED
        assert r.stats()["step_errors"] == 2

    def test_degraded_heals_after_clean_steps(self):
        plan = FaultPlan([FaultEvent(0, 0, "raise")])
        r = _stub_router(1, plan=plan, heal_steps=3)
        r.submit(_req("a", [1, 2, 3], max_new=12))
        r.step()
        assert r.replicas[0].state == DEGRADED
        for _ in range(3):
            r.step()
        assert r.replicas[0].state == HEALTHY

    def test_kill_revive_recovering_then_healthy(self):
        plan = FaultPlan([FaultEvent(1, 0, "kill"),
                          FaultEvent(3, 0, "revive")])
        r = _stub_router(1, plan=plan, recover_probe_steps=2)
        r.submit(_req("a", [1, 2, 3], max_new=8))
        r.step()
        r.step()                         # kill fires: work orphaned
        assert r.replicas[0].state == DEAD
        assert not r.replicas[0].serving
        r.step()                         # dead fleet idles
        r.step()                         # revive: recovering + re-homed
        assert r.replicas[0].state == RECOVERING
        for _ in range(12):
            r.step()
        st = r.stats()
        assert r.replicas[0].state == HEALTHY
        assert st["completed"] == 1 and st["lost"] == 0
        assert st["kills"] == 1 and st["revives"] == 1
        out = r.finished[0].output
        assert out == list(range(len(out)))   # replayed, no gap/dup

    def test_drain_failure_rebuilds_from_factory(self):
        eng = BrokenDrainEngine()
        r = Router([eng], factories=[StubEngine],
                   plan=FaultPlan([FaultEvent(0, 0, "raise")]))
        r.submit(_req("a", [1, 2, 3]))
        r.step()                         # raise, then drain blows up too
        assert r.replicas[0].engine is not eng      # rebuilt
        assert r.stats()["rebuilds"] == 1
        r.run()
        assert r.stats()["completed"] == 1 and r.stats()["lost"] == 0

    def test_drain_failure_without_factory_is_terminal(self):
        r = Router([BrokenDrainEngine()],
                   plan=FaultPlan([FaultEvent(0, 0, "raise"),
                                   FaultEvent(2, 0, "revive")]))
        r.submit(_req("a", [1, 2, 3]))
        for _ in range(4):
            r.step()
        rep = r.replicas[0]
        assert rep.state == DEAD and not rep.usable   # revive refused
        assert r.stats()["lost"] == 0                 # orphaned, accounted


# =============================================================================
# placement: rendezvous affinity + least-loaded fallback
# =============================================================================

class TestPlacement:
    def test_same_prefix_same_replica(self):
        r = _stub_router(4)
        toks = list(range(40))
        picks = {r._place(toks).rid for _ in range(5)}
        assert len(picks) == 1

    def test_rendezvous_kill_only_rehomes_dead_keys(self):
        r = _stub_router(4)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(1, 1000, size=24).tolist() for _ in range(40)]
        before = {i: r._place(p).rid for i, p in enumerate(prompts)}
        dead = before[0]
        survivors = [rep for rep in r.replicas if rep.rid != dead]
        moved = sum(1 for i, p in enumerate(prompts)
                    if r._place(p, among=survivors).rid != before[i])
        lost_keys = sum(1 for v in before.values() if v == dead)
        assert moved == lost_keys        # only the dead replica's keys move

    def test_least_loaded_override_beyond_slack(self):
        r = _stub_router(2, balance_slack_tokens=10)
        toks = list(range(32))
        primary = r._place(toks)
        # load the affinity target far past the slack
        heavy = _req("h", [9] * 8, max_new=100)
        r._live[primary.rid][heavy.request_id] = heavy
        assert r._place(toks).rid != primary.rid

    def test_submit_with_no_serving_replicas_raises(self):
        r = _stub_router(1, plan=FaultPlan([FaultEvent(0, 0, "kill")]))
        r.step()
        with pytest.raises(RuntimeError, match="no serving replicas"):
            r.submit(_req("a", [1]))


# =============================================================================
# degrade policy application
# =============================================================================

class TestDegrade:
    def test_kill_pins_survivors_fp8_and_tightens_restores(self):
        pol = DegradePolicy(force_fp8=True, restore_scale=0.5,
                            hysteresis_steps=2)
        plan = FaultPlan([FaultEvent(1, 0, "kill"),
                          FaultEvent(4, 0, "revive")])
        r = _stub_router(2, policy=pol, plan=plan)
        base = r.replicas[1].engine.restore_policy
        for i in range(2):
            r.submit(_req(f"a{i}", [7, i], max_new=30))
        r.step()
        r.step()                         # kill fired; decision active
        surv = r.replicas[1].engine
        assert surv.forced_mode == "fp8"
        assert surv.restore_policy.max_restore_bytes_per_step \
            == max(1, base.max_restore_bytes_per_step // 2)
        assert r.stats()["degrade_active"]
        assert r.stats()["fp8_dwell"][1] > 0
        # revive at 4: hysteresis dwells 2 more decisions, THEN fp16
        r.step()
        r.step()
        assert surv.forced_mode == "fp8"     # still dwelling
        r.step()
        r.step()
        assert surv.forced_mode == "fp16"    # re-probed after dwell
        assert surv.restore_policy is base   # grants restored
        assert not r.stats()["degrade_active"]

    def test_shed_beyond_budget_is_explicit_and_conserved(self):
        pol = DegradePolicy(shed_budget_tokens=20, hysteresis_steps=2)
        plan = FaultPlan([FaultEvent(0, 0, "kill")])
        r = _stub_router(2, policy=pol, plan=plan)
        assert r.submit(_req("pre", [1, 2], max_new=10))
        r.step()                         # kill: degrade activates
        # survivor owes ~12 tokens; this request's 2+30 blows the budget
        assert r.submit(_req("big", [3, 4], max_new=30)) is False
        st = r.stats()
        assert st["shed"] == 1 and st["lost"] == 0
        assert sum(st["shed_by_replica"].values()) == 1
        assert [q.request_id for q in r.shed_requests] == ["big"]
        r.run()
        st = r.stats()
        assert st["submitted"] == st["completed"] + st["shed"]

    def test_failover_resubmission_bypasses_shed(self):
        # already-admitted work is NEVER shed, however tight the budget
        pol = DegradePolicy(shed_budget_tokens=1, hysteresis_steps=2)
        plan = FaultPlan([FaultEvent(1, 0, "kill")])
        r = _stub_router(2, policy=pol, plan=plan)
        for i in range(4):
            r.submit(_req(f"a{i}", [5, i], max_new=8))
        r.run()
        st = r.stats()
        assert st["completed"] == 4 and st["shed"] == 0 and st["lost"] == 0

    def test_policy_decide_dwell(self):
        pol = DegradePolicy(hysteresis_steps=3)
        assert not pol.decide(2, 2).active
        assert pol.decide(1, 2).active           # activation is immediate
        out = [pol.decide(2, 2).active for _ in range(4)]
        assert out == [True, True, False, False]  # releases after dwell


# =============================================================================
# fault plans: determinism + serialization
# =============================================================================

class TestFaultPlan:
    def test_seeded_replayable_and_seed_sensitive(self):
        mk = lambda s: FaultPlan.seeded(s, replicas=3, steps=40, p_raise=.1,
                                        p_stall=.1, p_corrupt=.1, p_kill=.05)
        assert mk(7).events == mk(7).events
        assert mk(7).events != mk(8).events

    def test_seeded_never_extinguishes_fleet(self):
        for seed in range(10):
            plan = FaultPlan.seeded(seed, replicas=2, steps=60, p_kill=0.5,
                                    revive_after=5)
            dead = set()
            by_step = {}
            for ev in plan.events:
                by_step.setdefault(ev.step, []).append(ev)
            for s in sorted(by_step):    # revives fire before kills
                for ev in sorted(by_step[s], key=lambda e: e.kind != "revive"):
                    if ev.kind == "kill":
                        dead.add(ev.replica)
                    elif ev.kind == "revive":
                        dead.discard(ev.replica)
                    assert len(dead) < 2

    def test_dict_round_trip(self):
        plan = FaultPlan.seeded(3, replicas=2, steps=20, p_raise=.2,
                                p_stall=.2, p_kill=.1)
        assert FaultPlan.from_dict(plan.to_dict()).events == plan.events

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(0, 0, "meteor")

    def test_injector_fires_each_event_once(self):
        plan = FaultPlan([FaultEvent(0, 0, "stall", 25.0)])
        inj = FaultInjector(plan)
        eng = StubEngine()
        hook = inj.hook(0)
        inj.arm(0)
        hook(eng)
        hook(eng)                        # second call: already consumed
        assert eng.inject_stall_ms == 25.0
        assert len(inj.fired) == 1

    def test_raise_kind_raises_injected_fault(self):
        inj = FaultInjector(FaultPlan([FaultEvent(0, 1, "raise")]))
        inj.arm(0)
        with pytest.raises(InjectedFault):
            inj.hook(1)(StubEngine())


# =============================================================================
# virtual clock + step cost model
# =============================================================================

class TestVirtualClock:
    def test_deterministic_trajectory(self):
        def drive(plan):
            vc = VirtualClock()
            r = _stub_router(2, plan=plan, clock=vc,
                             cost_model=StepCostModel())
            for i in range(3):
                r.submit(_req(f"a{i}", [1, i], max_new=6))
            r.run()
            return vc.now
        assert drive(None) == drive(None)

    def test_stall_advances_clock_and_is_counted(self):
        plan = FaultPlan([FaultEvent(0, 0, "stall", 40.0)])
        base = VirtualClock()
        rb = _stub_router(1, clock=base, cost_model=StepCostModel())
        rb.submit(_req("a", [1, 2], max_new=4))
        rb.run()
        stalled = VirtualClock()
        rs = _stub_router(1, plan=plan, clock=stalled,
                          cost_model=StepCostModel())
        rs.submit(_req("a", [1, 2], max_new=4))
        rs.run()
        assert stalled.now == pytest.approx(base.now + 0.040)
        assert rs.stats()["stall_ms"] == 40.0

    def test_fp8_steps_cost_less(self):
        m = StepCostModel()
        assert m.step_ms("fp8", 10) < m.step_ms("fp16", 10)
        # prefill-chunk tokens ride the cheaper compute-bound rate
        assert m.step_ms("fp16", 0, 10) < m.step_ms("fp16", 10, 0)


# =============================================================================
# trace regression (satellite: empty-trace rate_stats)
# =============================================================================

class TestRateStatsEmpty:
    def test_empty_trace_does_not_crash(self):
        s = trace.rate_stats([], duration_s=10.0)
        assert s == {"mean_rate": 0.0, "max_rate": 0.0,
                     "min_rate": 0.0, "burstiness": 0.0}


# =============================================================================
# real engines: bit-exact failover, checksummed corruption fallback
# =============================================================================

@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, to_serving(params)


def _mk(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("n_slots", 4)
    kw.setdefault("capacity", 128)
    kw.setdefault("forced_mode", "fp16")
    kw.setdefault("block_size", 16)
    kw.setdefault("n_blocks", 24)
    kw.setdefault("chunk_tokens", 64)
    kw.setdefault("debug_invariants", True)
    return Engine(cfg, params, **kw)


def _shared_burst(cfg, n=5, max_new=16):
    rng = np.random.default_rng(3)
    sysp = rng.integers(1, cfg.vocab_size, size=32).tolist()
    return [Request(f"r{i}",
                    sysp + np.random.default_rng(11 * i + 1)
                    .integers(1, cfg.vocab_size, size=6).tolist(), max_new)
            for i in range(n)]


def _serve(router, reqs):
    for q in reqs:
        router.submit(q)
    router.run()
    return {q.request_id: tuple(q.output) for q in router.finished}


class TestEngineSubmitValidation:
    """Satellite: malformed requests fail at submit with clear errors,
    not steps later as scheduling failures."""

    def test_empty_prompt(self, tiny):
        with pytest.raises(ValueError, match="empty prompt"):
            _mk(tiny).submit(Request("e", [], 4))

    def test_nonpositive_max_new(self, tiny):
        with pytest.raises(ValueError, match="max_new=0 must be positive"):
            _mk(tiny).submit(Request("z", [1, 2], 0))
        with pytest.raises(ValueError, match="must be positive"):
            _mk(tiny).submit(Request("n", [1, 2], -3))

    def test_exceeds_capacity(self, tiny):
        e = _mk(tiny, capacity=64)
        with pytest.raises(ValueError, match="exceeds per-sequence capacity"):
            e.submit(Request("big", [1] * 60, 8))

    def test_exceeds_whole_pool(self, tiny):
        # fits per-sequence capacity, but needs more blocks than the
        # whole pool holds: no amount of preemption can ever cover it
        e = _mk(tiny, capacity=128, n_blocks=4)
        with pytest.raises(ValueError, match="whole group pool"):
            e.submit(Request("pool", [1] * 100, 20))


class TestFailoverBitExact:
    def test_kill_mid_generation_is_bit_exact(self, tiny):
        cfg, _ = tiny
        # slack small enough that the shared-prefix burst spreads over
        # BOTH replicas: the survivor is warm when the failover arrives
        baseline = _serve(
            Router([_mk(tiny), _mk(tiny)], affinity_blocks=1,
                   balance_slack_tokens=60),
            _shared_burst(cfg))
        plan = FaultPlan([FaultEvent(4, 0, "kill")])
        r = Router([_mk(tiny), _mk(tiny)], plan=plan, affinity_blocks=1,
                   balance_slack_tokens=60)
        faulted = _serve(r, _shared_burst(cfg))
        st = r.stats()
        assert st["kills"] == 1 and st["lost"] == 0
        assert st["replicas"][0] == DEAD
        assert st["failover_requests"] > 0
        # the survivor's warm prefix cache serves part of the replayed
        # streams; the rest is recomputed — both paths are counted and
        # both land on the same tokens
        assert st["failover_restored_tokens"] > 0
        assert st["failover_recomputed_tokens"] > 0
        assert faulted == baseline       # bit-identical continuation

    def test_step_raise_failover_is_bit_exact(self, tiny):
        cfg, _ = tiny
        baseline = _serve(
            Router([_mk(tiny), _mk(tiny)], affinity_blocks=1,
                   balance_slack_tokens=60),
            _shared_burst(cfg, n=4, max_new=10))
        plan = FaultPlan([FaultEvent(3, 1, "raise")])
        r = Router([_mk(tiny), _mk(tiny)], plan=plan, affinity_blocks=1,
                   balance_slack_tokens=60)
        faulted = _serve(r, _shared_burst(cfg, n=4, max_new=10))
        st = r.stats()
        assert st["step_errors"] == 1 and st["lost"] == 0
        assert st["replicas"][1] in (DEGRADED, HEALTHY)
        assert faulted == baseline


class TestCorruptionFallback:
    def test_corrupt_host_entry_detected_and_recomputed(self, tiny):
        cfg, _ = tiny

        def serve_phases(corrupt):
            # scarce pool: burst B evicts burst A's prefix blocks into
            # the host tier, so a third burst sharing A's prefix goes
            # through host restore — the corruption target
            eng = _mk(tiny, n_slots=2, n_blocks=8, capacity=128)
            r1 = Router([eng], affinity_blocks=1)
            _serve(r1, _shared_burst(cfg, n=2, max_new=6))
            rng = np.random.default_rng(99)
            other = rng.integers(1, cfg.vocab_size, size=100).tolist()
            _serve(r1, [Request("evict", other, 6)])
            assert len(eng.blocks.host.entries) > 0   # A spilled to host
            plan = FaultPlan([FaultEvent(0, 0, "corrupt")]) \
                if corrupt else None
            r2 = Router([eng], plan=plan, affinity_blocks=1)
            r2.replicas[0].fin_cursor = len(eng.finished)
            burst = [Request(f"again{i}", q.tokens, 6)
                     for i, q in enumerate(_shared_burst(cfg, n=2,
                                                         max_new=6))]
            out = _serve(r2, burst)
            return out, r2.stats()

        ref, ref_st = serve_phases(corrupt=False)
        hit, hit_st = serve_phases(corrupt=True)
        assert ref_st["corrupt_detected"] == 0
        assert hit_st["corrupt_detected"] > 0    # checksum caught the flip
        assert hit_st["lost"] == 0
        assert hit == ref                        # recomputed, never wrong


class TestRouterBuild:
    def test_build_replicas_with_factories(self, tiny):
        cfg, params = tiny
        r = Router.build(cfg, params, 2,
                         engine_kwargs=dict(n_slots=2, capacity=64,
                                            forced_mode="fp16",
                                            block_size=16, n_blocks=11,
                                            chunk_tokens=32))
        assert len(r.replicas) == 2
        assert all(rep.factory is not None for rep in r.replicas)
        out = _serve(r, _shared_burst(cfg, n=2, max_new=4))
        assert len(out) == 2 and r.stats()["lost"] == 0

    @pytest.mark.skipif(len(jax.devices()) < 4,
                        reason="needs 4 devices (chaos/mesh lane forces "
                               "--xla_force_host_platform_device_count=4)")
    def test_replica_mesh_slices_failover(self, tiny):
        from repro.launch.mesh import make_replica_meshes
        cfg, params = tiny
        meshes = make_replica_meshes(2, 2)
        assert not (set(meshes[0].devices.flat)
                    & set(meshes[1].devices.flat))
        plan = FaultPlan([FaultEvent(3, 0, "kill")])
        r = Router.build(cfg, params, 2, meshes=meshes, plan=plan,
                         affinity_blocks=1,
                         engine_kwargs=dict(n_slots=2, capacity=64,
                                            forced_mode="fp16",
                                            block_size=16, n_blocks=11,
                                            chunk_tokens=32))
        out = _serve(r, _shared_burst(cfg, n=3, max_new=8))
        st = r.stats()
        assert len(out) == 3 and st["lost"] == 0 and st["kills"] == 1
