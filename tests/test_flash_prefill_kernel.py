"""Flash prefill attention Pallas kernel vs the materialized-scores oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_prefill_attention import flash_prefill_attention
from repro.models.layers import attn_core_train

RNG = np.random.RandomState(3)


@pytest.mark.parametrize("shape", [(2, 512, 8, 4, 64), (1, 1024, 4, 2, 128),
                                   (2, 256, 16, 16, 64)])
@pytest.mark.parametrize("block", [(256, 256), (128, 256)])
def test_matches_causal_oracle(shape, block):
    b, s, h, hkv, d = shape
    if s % block[0] or s % block[1]:
        pytest.skip("block does not divide")
    q = jnp.asarray(RNG.randn(b, s, h, d).astype(np.float16))
    k = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float16))
    v = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float16))
    got = flash_prefill_attention(q, k, v, block=block, interpret=True)
    want = attn_core_train(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_first_token_attends_only_itself():
    b, s, h, hkv, d = 1, 256, 4, 4, 64
    q = jnp.asarray(RNG.randn(b, s, h, d).astype(np.float16))
    k = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float16))
    v = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float16))
    out = np.asarray(flash_prefill_attention(q, k, v, block=(128, 128),
                                             interpret=True))
    np.testing.assert_allclose(out[0, 0], np.asarray(v[0, 0], np.float32),
                               rtol=2e-3, atol=2e-3)
