"""SSD chunked-scan correctness vs. naive per-step recurrence oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.mamba2 import ssd_chunked, ssd_decode_step


def naive_ssd(x, dt, A, B, C, D):
    """Literal recurrence: S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    S = np.zeros((b, h, p, n))
    y = np.zeros((b, l, h, p))
    for t in range(l):
        for bi in range(b):
            for hi in range(h):
                gi = hi // rep
                dA = np.exp(dt[bi, t, hi] * A[hi])
                S[bi, hi] = dA * S[bi, hi] + dt[bi, t, hi] * np.outer(
                    x[bi, t, hi], B[bi, t, gi])
                y[bi, t, hi] = S[bi, hi] @ C[bi, t, gi] + D[hi] * x[bi, t, hi]
    return y, S


def _inputs(b=2, l=48, h=4, p=8, g=2, n=6, seed=0):
    r = np.random.RandomState(seed)
    x = r.randn(b, l, h, p).astype(np.float32)
    dt = r.uniform(0.01, 0.2, (b, l, h)).astype(np.float32)
    A = -r.uniform(0.5, 2.0, h).astype(np.float32)
    B = r.randn(b, l, g, n).astype(np.float32)
    C = r.randn(b, l, g, n).astype(np.float32)
    D = r.randn(h).astype(np.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("chunk", [8, 16, 48, 64])
def test_chunked_matches_naive(chunk):
    x, dt, A, B, C, D = _inputs()
    y_ref, S_ref = naive_ssd(x, dt, A, B, C, D)
    y, S = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(B), jnp.asarray(C), jnp.asarray(D),
                       chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_decode_step_continues_prefill_state():
    """prefill(L) state + decode steps == prefill(L + extra)."""
    x, dt, A, B, C, D = _inputs(l=40)
    full_y, full_S = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                                 jnp.asarray(B), jnp.asarray(C), jnp.asarray(D),
                                 chunk=16)
    split = 32
    _, S = ssd_chunked(jnp.asarray(x[:, :split]), jnp.asarray(dt[:, :split]),
                       jnp.asarray(A), jnp.asarray(B[:, :split]),
                       jnp.asarray(C[:, :split]), jnp.asarray(D), chunk=16)
    ys = []
    for t in range(split, 40):
        y1, S = ssd_decode_step(S, jnp.asarray(x[:, t]), jnp.asarray(dt[:, t]),
                                jnp.asarray(A), jnp.asarray(B[:, t]),
                                jnp.asarray(C[:, t]), jnp.asarray(D))
        ys.append(np.asarray(y1))
    got = np.stack(ys, axis=1)
    np.testing.assert_allclose(got, np.asarray(full_y[:, split:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(full_S),
                               rtol=2e-4, atol=2e-4)


def test_state_decays_not_explodes():
    x, dt, A, B, C, D = _inputs(l=96)
    _, S = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(B), jnp.asarray(C), jnp.asarray(D), chunk=32)
    assert np.all(np.isfinite(np.asarray(S)))
