"""Exhaustive + property tests for the NestedFP format (paper §4.2).

The central claims:
  1. encode→decode is BIT-EXACT for every applicable f16 value (lossless).
  2. the upper byte, bitcast to float8_e4m3fn, equals RNE(w * 2^8) — i.e.
     NestedFP8 is exactly E4M3 quantization with global scale 256.
We check claim 1 exhaustively over all 2^16 f16 bit patterns inside the
applicability window, and claim 2 exhaustively against ml_dtypes casting.
"""

import numpy as np
import jax
import jax.numpy as jnp
import ml_dtypes
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import nestedfp as nf


def _all_applicable_f16() -> np.ndarray:
    bits = np.arange(1 << 16, dtype=np.uint16)
    mag = bits & 0x7FFF
    return bits[mag <= nf.F16_NESTED_ABS_MAX_BITS].view(np.float16)


class TestExhaustive:
    def test_roundtrip_bit_exact_all_applicable_values(self):
        w = _all_applicable_f16()
        upper, lower = nf.encode(jnp.asarray(w))
        back = np.asarray(nf.decode(upper, lower))
        np.testing.assert_array_equal(back.view(np.uint16), w.view(np.uint16))

    def test_roundtrip_numpy_twin_matches_jax(self):
        w = _all_applicable_f16()
        uj, lj = nf.encode(jnp.asarray(w))
        un, ln = nf.encode_np(w)
        np.testing.assert_array_equal(np.asarray(uj), un)
        np.testing.assert_array_equal(np.asarray(lj), ln)
        np.testing.assert_array_equal(nf.decode_np(un, ln).view(np.uint16),
                                      w.view(np.uint16))

    def test_upper_is_exact_e4m3_rne_of_scaled_value(self):
        """upper bitcast e4m3fn == (f32(w) * 256) cast-RNE to e4m3fn."""
        w = _all_applicable_f16()
        upper, _ = nf.encode_np(w)
        ours = upper.view(ml_dtypes.float8_e4m3fn)
        ref = (w.astype(np.float32) * 256.0).astype(ml_dtypes.float8_e4m3fn)
        np.testing.assert_array_equal(ours.view(np.uint8), ref.view(np.uint8))

    def test_upper_never_nan_or_out_of_range(self):
        w = _all_applicable_f16()
        upper, _ = nf.encode_np(w)
        vals = upper.view(ml_dtypes.float8_e4m3fn).astype(np.float32)
        assert not np.any(np.isnan(vals))
        assert np.abs(vals).max() <= nf.E4M3_MAX

    def test_applicability_threshold_is_exactly_1p75(self):
        assert bool(nf.is_applicable(jnp.float16(1.75)))
        assert bool(nf.is_applicable(jnp.float16(-1.75)))
        # next representable f16 above 1.75 must be excluded
        nxt = np.nextafter(np.float16(1.75), np.float16(np.inf), dtype=np.float16)
        assert not bool(nf.is_applicable(jnp.asarray(nxt)))
        assert not bool(nf.is_applicable(jnp.float16(np.inf)))
        assert not bool(nf.is_applicable(jnp.float16(np.nan)))

    def test_signed_zero_and_subnormals(self):
        w = np.array([0.0, -0.0, 2**-24, -(2**-24), 2**-14], dtype=np.float16)
        u, l = nf.encode_np(w)
        np.testing.assert_array_equal(nf.decode_np(u, l).view(np.uint16),
                                      w.view(np.uint16))
        # -0.0 upper must be e4m3 -0 so FP8 GEMMs see the sign
        assert u[1] == 0x80 and u[0] == 0x00

    def test_checksum_invariant_no_underflow(self):
        """(upper&0x7F) - (lower>>7) >= 0 for every applicable value."""
        w = _all_applicable_f16()
        u, l = nf.encode_np(w)
        assert np.all((u.astype(np.int32) & 0x7F) - (l.astype(np.int32) >> 7) >= 0)


class TestProperty:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.floats(-1.75, 1.75, width=16, allow_nan=False),
                    min_size=1, max_size=256))
    def test_roundtrip_random_arrays(self, vals):
        w = np.asarray(vals, dtype=np.float16)
        t = nf.NestedTensor.from_f16(jnp.asarray(w))
        assert not t.is_exception
        np.testing.assert_array_equal(
            np.asarray(t.read_f16()).view(np.uint16), w.view(np.uint16))

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(-1.75, 1.75, width=16, allow_nan=False),
                    min_size=1, max_size=128))
    def test_fp8_error_bounded_by_e4m3_ulp(self, vals):
        """|dequant(upper) - w| <= 2^-4 * 2^floor(log2|w|) (e4m3 half-ulp)."""
        w = np.asarray(vals, dtype=np.float16)
        u, _ = nf.encode_np(w)
        deq = u.view(ml_dtypes.float8_e4m3fn).astype(np.float64) * 2.0**-8
        wf = w.astype(np.float64)
        # half-ulp of e4m3 at the value's scale; subnormal floor 2^-9 * 2^-8
        scale = np.where(np.abs(wf) > 0, 2.0 ** np.floor(np.log2(np.maximum(np.abs(wf), 2**-14))), 1.0)
        tol = np.maximum(scale * 2.0**-4, 2.0**-18)
        assert np.all(np.abs(deq - wf) <= tol)


class TestNestedTensor:
    def test_exception_tensor_roundtrip(self):
        w = jnp.asarray(np.array([[0.5, 3.0], [1.0, -2.5]], np.float16))
        t = nf.NestedTensor.from_f16(w)
        assert t.is_exception
        np.testing.assert_array_equal(np.asarray(t.read_f16()), np.asarray(w))
        with pytest.raises(ValueError):
            t.read_fp8()

    def test_pytree_registration(self):
        w = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (8, 8)).astype(np.float16))
        t = nf.NestedTensor.from_f16(w)
        leaves, treedef = jax.tree_util.tree_flatten(t)
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(t2.read_f16()), np.asarray(w))

    def test_jit_through_decode(self):
        w = jnp.asarray(np.random.RandomState(1).uniform(-1.5, 1.5, (32, 16)).astype(np.float16))
        t = nf.NestedTensor.from_f16(w)
        f = jax.jit(lambda tt: tt.read_f16())
        np.testing.assert_array_equal(np.asarray(f(t)), np.asarray(w))

    def test_split_stats(self):
        w = jnp.asarray(np.array([0.1, 1.9], np.float16))
        s = nf.split_stats(w)
        assert s["tensor_applicable"] is False
        assert 0.4 < s["applicable_fraction"] < 0.6
