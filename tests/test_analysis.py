"""repro-lint golden tests.

Each rule has a bad/good fixture pair under ``analysis_fixtures/``: the
bad file carries ``# expect: NFP00x`` trailing comments on the exact
lines the analyzer must flag (rule id AND line number are asserted),
the good file is the idiomatic rewrite and must scan clean.  On top of
the pairs: the suppression directive, the malformed-directive rule
(NFP000), baseline round-trip/staleness, and the acceptance check that
the repo itself lints clean modulo the committed baseline.
"""

import json
import re
from pathlib import Path

import pytest

from repro.analysis import Finding, run_analysis
from repro.analysis import baseline
from repro.analysis.cli import main

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"

BAD = ["nfp001_bad.py", "nfp002_bad.py", "nfp003_bad.py",
       "nfp004_bad.py", "nfp005_bad.py"]
GOOD = ["nfp001_good.py", "nfp002_good.py", "nfp003_good.py",
        "nfp004_good.py", "nfp005_good.py"]


def _findings(name: str) -> list[Finding]:
    findings, _modules = run_analysis([FIXTURES / name], REPO)
    return findings


def _expected(name: str) -> list[tuple[str, int]]:
    """(rule, line) pairs from the fixture's `# expect:` markers."""
    out = []
    for i, line in enumerate((FIXTURES / name).read_text().splitlines(), 1):
        m = re.search(r"# expect: (NFP\d{3})", line)
        if m:
            out.append((m.group(1), i))
    assert out, f"{name} declares no expectations"
    return out


# -- golden pairs -------------------------------------------------------------

@pytest.mark.parametrize("name", BAD)
def test_bad_fixture_exact_findings(name):
    got = sorted((f.rule, f.line) for f in _findings(name) if f.active)
    assert got == sorted(_expected(name))


@pytest.mark.parametrize("name", GOOD)
def test_good_fixture_scans_clean(name):
    assert [f for f in _findings(name) if f.active] == []


# -- directives ---------------------------------------------------------------

def test_inline_suppression_keeps_finding_but_not_active():
    fs = _findings("nfp001_suppressed.py")
    assert len(fs) == 1
    f = fs[0]
    assert f.rule == "NFP001" and f.suppressed and not f.active
    assert "suppression syntax" in f.suppress_reason


def test_malformed_directives_report_nfp000():
    src = (FIXTURES / "nfp000_malformed.py").read_text().splitlines()
    bad_lines = [i for i, l in enumerate(src, 1) if "# nfp:" in l]
    fs = _findings("nfp000_malformed.py")
    assert sorted((f.rule, f.line) for f in fs) \
        == [("NFP000", i) for i in bad_lines]
    assert all(f.active for f in fs)        # malformed directives FAIL the run


# -- baseline -----------------------------------------------------------------

def test_baseline_key_is_line_independent():
    a = Finding("NFP001", "a.py", 10, 0, "msg", "mod.fn")
    b = Finding("NFP001", "a.py", 99, 4, "msg", "mod.fn")
    assert a.key() == b.key()
    c = Finding("NFP002", "a.py", 10, 0, "msg", "mod.fn")
    assert a.key() != c.key()


def test_baseline_roundtrip_marks_everything(tmp_path):
    bl = tmp_path / "bl.json"
    baseline.save(bl, _findings("nfp001_bad.py"))
    fs = _findings("nfp001_bad.py")
    matched, stale = baseline.apply(bl, fs)
    assert matched == len(fs) and stale == 0
    assert all(f.baselined and not f.active for f in fs)


def test_baseline_stale_entries_are_counted(tmp_path):
    bl = tmp_path / "bl.json"
    baseline.save(bl, _findings("nfp001_bad.py"))
    fs = _findings("nfp002_bad.py")             # none of these match
    matched, stale = baseline.apply(bl, fs)
    assert matched == 0
    assert stale == len(_findings("nfp001_bad.py"))
    assert all(f.active for f in fs)


# -- CLI ----------------------------------------------------------------------

def test_cli_exit_one_on_seeded_violation(tmp_path, capsys):
    rc = main([str(FIXTURES / "nfp001_bad.py"), "--repo-root", str(REPO)])
    assert rc == 1
    assert "NFP001" in capsys.readouterr().out


def test_cli_exit_zero_on_clean_file(capsys):
    rc = main([str(FIXTURES / "nfp001_good.py"), "--repo-root", str(REPO)])
    assert rc == 0


def test_cli_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    main([str(FIXTURES / "nfp001_bad.py"), "--repo-root", str(REPO),
          "--json", str(out)])
    data = json.loads(out.read_text())
    assert data["summary"]["active_by_rule"] == {"NFP001": 4}
    assert {f["rule"] for f in data["findings"]} == {"NFP001"}
    assert all(f["key"] for f in data["findings"])


def test_cli_update_then_check_with_baseline(tmp_path, capsys):
    bad = str(FIXTURES / "nfp001_bad.py")
    bl = tmp_path / "bl.json"
    assert main([bad, "--repo-root", str(REPO), "--baseline", str(bl),
                 "--update-baseline"]) == 0
    assert main([bad, "--repo-root", str(REPO),
                 "--baseline", str(bl)]) == 0


# -- acceptance: the repo itself ---------------------------------------------

def test_repo_lints_clean_modulo_committed_baseline(capsys):
    rc = main(["--repo-root", str(REPO),
               "--baseline", str(REPO / "nfp-baseline.json")])
    out = capsys.readouterr().out
    assert rc == 0, f"new repro-lint findings:\n{out}"
