"""Planar (NestedKV) decode-attention Pallas kernel vs oracles:
fp16 path must match exact-f16-cache attention; fp8 path must match
attention over the e5m2-truncated cache. Sweeps shapes/lengths."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import nestedfp as nf
from repro.kernels.planar_decode_attention import planar_decode_attention
from repro.models.layers import attn_core_decode

RNG = np.random.RandomState(7)


def _setup(b, h, hkv, d, cap):
    q = jnp.asarray(RNG.randn(b, h, d).astype(np.float16))
    k = jnp.asarray(RNG.randn(b, cap, hkv, d).astype(np.float16))
    v = jnp.asarray(RNG.randn(b, cap, hkv, d).astype(np.float16))
    lens = jnp.asarray(RNG.randint(1, cap, b), jnp.int32)
    return q, k, v, lens


@pytest.mark.parametrize("shape", [(2, 8, 4, 64, 512), (3, 4, 4, 128, 1024),
                                   (1, 16, 2, 64, 256)])
@pytest.mark.parametrize("block_c", [128, 256])
def test_fp16_matches_exact_oracle(shape, block_c):
    b, h, hkv, d, cap = shape
    q, k, v, lens = _setup(b, h, hkv, d, cap)
    k_hi, k_lo = nf.split_bytes(k)
    v_hi, v_lo = nf.split_bytes(v)
    got = planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, lens,
                                  fp8=False, block_c=block_c, interpret=True)
    want = attn_core_decode(q[:, None], k, v, lens)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(2, 8, 4, 64, 512), (1, 16, 2, 64, 256)])
def test_fp8_matches_e5m2_oracle(shape):
    b, h, hkv, d, cap = shape
    q, k, v, lens = _setup(b, h, hkv, d, cap)
    k_hi, _ = nf.split_bytes(k)
    v_hi, _ = nf.split_bytes(v)
    k8 = nf.e5m2_view(k_hi, jnp.float16)
    v8 = nf.e5m2_view(v_hi, jnp.float16)
    got = planar_decode_attention(q, k_hi, k_hi, v_hi, v_hi, lens,
                                  fp8=True, block_c=128, interpret=True)
    want = attn_core_decode(q[:, None], k8, v8, lens)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_length_one_and_full(shape=(2, 4, 4, 64, 256)):
    b, h, hkv, d, cap = shape
    q, k, v, _ = _setup(b, h, hkv, d, cap)
    k_hi, k_lo = nf.split_bytes(k)
    v_hi, v_lo = nf.split_bytes(v)
    for lens in ([1, cap], [cap, 1]):
        la = jnp.asarray(lens, jnp.int32)
        got = planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, la,
                                      fp8=False, block_c=128, interpret=True)
        want = attn_core_decode(q[:, None], k, v, la)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
