"""Planar (NestedKV) decode-attention Pallas kernel vs oracles:
fp16 path must match exact-f16-cache attention; fp8 path must match
attention over the e5m2-truncated cache. Sweeps shapes/lengths, plus a
sliding-window (gemma3 local-layer) case on the paged variant against
the dense `_causal_window_mask` arithmetic at window-boundary
positions."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import nestedfp as nf
from repro.kernels.planar_decode_attention import (
    paged_planar_decode_attention, planar_decode_attention)
from repro.models.layers import attn_core_decode

RNG = np.random.RandomState(7)


def _setup(b, h, hkv, d, cap):
    q = jnp.asarray(RNG.randn(b, h, d).astype(np.float16))
    k = jnp.asarray(RNG.randn(b, cap, hkv, d).astype(np.float16))
    v = jnp.asarray(RNG.randn(b, cap, hkv, d).astype(np.float16))
    lens = jnp.asarray(RNG.randint(1, cap, b), jnp.int32)
    return q, k, v, lens


@pytest.mark.parametrize("shape", [(2, 8, 4, 64, 512), (3, 4, 4, 128, 1024),
                                   (1, 16, 2, 64, 256)])
@pytest.mark.parametrize("block_c", [128, 256])
def test_fp16_matches_exact_oracle(shape, block_c):
    b, h, hkv, d, cap = shape
    q, k, v, lens = _setup(b, h, hkv, d, cap)
    k_hi, k_lo = nf.split_bytes(k)
    v_hi, v_lo = nf.split_bytes(v)
    got = planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, lens,
                                  fp8=False, block_c=block_c, interpret=True)
    want = attn_core_decode(q[:, None], k, v, lens)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(2, 8, 4, 64, 512), (1, 16, 2, 64, 256)])
def test_fp8_matches_e5m2_oracle(shape):
    b, h, hkv, d, cap = shape
    q, k, v, lens = _setup(b, h, hkv, d, cap)
    k_hi, _ = nf.split_bytes(k)
    v_hi, _ = nf.split_bytes(v)
    k8 = nf.e5m2_view(k_hi, jnp.float16)
    v8 = nf.e5m2_view(v_hi, jnp.float16)
    got = planar_decode_attention(q, k_hi, k_hi, v_hi, v_hi, lens,
                                  fp8=True, block_c=128, interpret=True)
    want = attn_core_decode(q[:, None], k8, v8, lens)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def _shuffled_pool(rng, b, cap, hkv, d, bs, mb):
    """Logical (B, Cap) K/V plus a shuffled physical pool + block
    tables realizing the same logical layout."""
    nb = b * mb + 1
    k = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
    v = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
    tables = np.zeros((b, mb), np.int32)
    ids = list(range(1, nb))
    rng.shuffle(ids)
    pool_k = np.zeros((nb, bs, hkv, d), np.float16)
    pool_v = np.zeros((nb, bs, hkv, d), np.float16)
    t = 0
    for bb in range(b):
        for m in range(mb):
            pid = ids[t]
            t += 1
            tables[bb, m] = pid
            pool_k[pid] = np.asarray(k[bb, m * bs: (m + 1) * bs])
            pool_v[pid] = np.asarray(v[bb, m * bs: (m + 1) * bs])
    return k, v, jnp.asarray(tables), jnp.asarray(pool_k), jnp.asarray(pool_v)


@pytest.mark.parametrize("fp8", [False, True])
def test_windowed_paged_matches_window_mask_reference(fp8):
    """Sliding-window (gemma3 local-layer) paged decode: the kernel's
    window mask must reproduce the dense `_causal_window_mask`
    arithmetic (attn_core_decode applies the same `_apply_window`
    predicate) at the boundary positions — len == window, window +- 1,
    and a length whose window crosses a physical block boundary."""
    b, h, hkv, d = 4, 8, 4, 64
    bs, mb, window = 16, 4, 24            # window spans 1.5 blocks
    cap = bs * mb
    rng = np.random.RandomState(13)
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
    k, v, tables, pk, pv = _shuffled_pool(rng, b, cap, hkv, d, bs, mb)
    # boundaries: exactly the window, one inside, one outside, and a
    # length where [len-window, len) straddles a block edge (40-24=16)
    lens = jnp.asarray([window, window - 1, window + 1, 40], jnp.int32)
    k_hi, k_lo = nf.split_bytes(pk)
    v_hi, v_lo = nf.split_bytes(pv)
    got = paged_planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, tables,
                                        lens, fp8=fp8, window=window,
                                        interpret=True)
    if fp8:
        k = nf.e5m2_view(nf.split_bytes(k)[0], jnp.float16)
        v = nf.e5m2_view(nf.split_bytes(v)[0], jnp.float16)
    want = attn_core_decode(q[:, None], k, v, lens, window=window)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    # the window must actually bite: a global run over the same pool
    # differs for every row longer than the window
    glob = paged_planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, tables,
                                         lens, fp8=fp8, interpret=True)
    assert np.abs(np.asarray(got)[[0, 2, 3]]
                  - np.asarray(glob)[[0, 2, 3]]).max() > 1e-4


def test_windowed_dense_planar_matches_reference():
    """The fixed-slot planar kernel honors the same window mask."""
    b, h, hkv, d, cap = 2, 8, 4, 64, 256
    q, k, v, _ = _setup(b, h, hkv, d, cap)
    lens = jnp.asarray([cap, 97], jnp.int32)
    window = 33
    k_hi, k_lo = nf.split_bytes(k)
    v_hi, v_lo = nf.split_bytes(v)
    got = planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, lens,
                                  fp8=False, block_c=128, window=window,
                                  interpret=True)
    want = attn_core_decode(q[:, None], k, v, lens, window=window)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("fp8", [False, True])
def test_traced_window_bit_equals_static_window(fp8):
    """The engine's scanned decoder stack passes the per-layer window as
    a TRACED (1,) operand (`window_arr`); its mask arithmetic must be
    bit-identical to the static `window=` kwarg at every boundary —
    len == window, window +- 1, a window crossing a physical block edge
    — and window_arr <= 0 must be bit-identical to no window at all."""
    b, h, hkv, d = 4, 8, 4, 64
    bs, mb, window = 16, 4, 24
    rng = np.random.RandomState(29)
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
    _, _, tables, pk, pv = _shuffled_pool(rng, b, 64, hkv, d, bs, mb)
    lens = jnp.asarray([window, window - 1, window + 1, 40], jnp.int32)
    k_hi, k_lo = nf.split_bytes(pk)
    v_hi, v_lo = nf.split_bytes(pv)
    static = paged_planar_decode_attention(
        q, k_hi, k_lo, v_hi, v_lo, tables, lens, fp8=fp8, window=window,
        interpret=True)
    traced = paged_planar_decode_attention(
        q, k_hi, k_lo, v_hi, v_lo, tables, lens, fp8=fp8,
        window_arr=jnp.asarray([window], jnp.int32), interpret=True)
    assert (np.asarray(static) == np.asarray(traced)).all()
    glob = paged_planar_decode_attention(
        q, k_hi, k_lo, v_hi, v_lo, tables, lens, fp8=fp8, interpret=True)
    disabled = paged_planar_decode_attention(
        q, k_hi, k_lo, v_hi, v_lo, tables, lens, fp8=fp8,
        window_arr=jnp.asarray([0], jnp.int32), interpret=True)
    assert (np.asarray(glob) == np.asarray(disabled)).all()
    assert np.abs(np.asarray(static) - np.asarray(glob)).max() > 1e-4


def test_paged_bit_equals_dense_on_identity_layout():
    """Plane-rejoin exactness: with an identity block layout and the
    dense kernel's cache block == the paged block size, both kernels
    run the SAME online-softmax grid over the SAME f16 bytes, so the
    paged gather through scalar-prefetch tables must be BIT-exact vs
    the dense-slot kernel — in fp16 (both planes rejoined) and fp8
    (hi-plane truncation only)."""
    b, h, hkv, d, bs, mb = 2, 8, 4, 64, 128, 4
    cap = bs * mb
    rng = np.random.RandomState(31)
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
    k = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
    v = jnp.asarray(rng.randn(b, cap, hkv, d).astype(np.float16))
    lens = jnp.asarray([cap - 3, 77], jnp.int32)
    # identity layout: row r's logical block m lives at pool id 1+r*mb+m
    pool_k = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, d), jnp.float16),
         k.reshape(b * mb, bs, hkv, d)])
    pool_v = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, d), jnp.float16),
         v.reshape(b * mb, bs, hkv, d)])
    tables = jnp.asarray(1 + np.arange(b * mb).reshape(b, mb), jnp.int32)
    for fp8 in (False, True):
        dk_hi, dk_lo = nf.split_bytes(k)
        dv_hi, dv_lo = nf.split_bytes(v)
        dense = planar_decode_attention(q, dk_hi, dk_lo, dv_hi, dv_lo,
                                        lens, fp8=fp8, block_c=bs,
                                        interpret=True)
        pk_hi, pk_lo = nf.split_bytes(pool_k)
        pv_hi, pv_lo = nf.split_bytes(pool_v)
        paged = paged_planar_decode_attention(q, pk_hi, pk_lo, pv_hi,
                                              pv_lo, tables, lens,
                                              fp8=fp8, interpret=True)
        assert (np.asarray(dense) == np.asarray(paged)).all(), \
            f"paged != dense bitwise (fp8={fp8})"


def test_windowed_traced_boundary_matches_reference():
    """Traced-window kernel vs the dense `_causal_window_mask` oracle at
    the same boundary positions the static-window sweep covers."""
    b, h, hkv, d = 4, 8, 4, 64
    bs, mb, window = 16, 4, 24
    rng = np.random.RandomState(37)
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float16))
    k, v, tables, pk, pv = _shuffled_pool(rng, b, bs * mb, hkv, d, bs, mb)
    lens = jnp.asarray([window, window - 1, window + 1, 40], jnp.int32)
    k_hi, k_lo = nf.split_bytes(pk)
    v_hi, v_lo = nf.split_bytes(pv)
    got = paged_planar_decode_attention(
        q, k_hi, k_lo, v_hi, v_lo, tables, lens,
        window_arr=jnp.asarray([window], jnp.int32), interpret=True)
    want = attn_core_decode(q[:, None], k, v, lens, window=window)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_length_one_and_full(shape=(2, 4, 4, 64, 256)):
    b, h, hkv, d, cap = shape
    q, k, v, _ = _setup(b, h, hkv, d, cap)
    k_hi, k_lo = nf.split_bytes(k)
    v_hi, v_lo = nf.split_bytes(v)
    for lens in ([1, cap], [cap, 1]):
        la = jnp.asarray(lens, jnp.int32)
        got = planar_decode_attention(q, k_hi, k_lo, v_hi, v_lo, la,
                                      fp8=False, block_c=128, interpret=True)
        want = attn_core_decode(q[:, None], k, v, la)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
