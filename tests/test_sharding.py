"""Sharding resolver + roofline parser unit tests, and a small-mesh pjit
integration test run in a subprocess (device count must be forced before
jax initializes)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

# small-mesh subprocess integration + resolver sweep — slow lane
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch import steps
from repro.roofline import analysis as roof
from repro.roofline import flops as fcount


class FakeMesh:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def _spec_for(arch, keypath, shape):
    """Resolve a param spec through the public rule."""
    from repro.launch.sharding import param_spec

    class Key:
        def __init__(self, k):
            self.key = k
    path = tuple(Key(k) for k in keypath)
    return param_spec(path, jax.ShapeDtypeStruct(shape, jnp.float32),
                      ARCHS[arch], FakeMesh())


class TestResolverRules:
    def test_qwen3_attention_sharded_over_heads(self):
        s = _spec_for("qwen3-8b", ("layers", "attn", "wq", "w"),
                      (36, 4096, 4096))
        assert s == P(None, None, "model")

    def test_gemma3_few_heads_row_parallel(self):
        """4 heads % 16 != 0 -> fall back to sharding the d_model
        contraction dim (row-parallel) so weights still distribute."""
        s = _spec_for("gemma3-1b", ("layers", "attn", "wq", "w"),
                      (26, 1152, 1024))
        assert s == P(None, "model", None)   # 1152 % 16 == 0

    def test_deepseek_coder_odd_heads_row_parallel(self):
        s = _spec_for("deepseek-coder-33b", ("layers", "attn", "wq", "w"),
                      (62, 7168, 7168))
        assert s == P(None, "model", None)
        s = _spec_for("deepseek-coder-33b", ("layers", "attn", "wo", "w"),
                      (62, 7168, 7168))
        assert s == P(None, None, "model")

    def test_gemma3_mlp_still_sharded(self):
        s = _spec_for("gemma3-1b", ("layers", "mlp", "gate", "w"),
                      (26, 1152, 6912))
        assert s == P(None, None, "model")

    def test_dsv3_experts_full_ep(self):
        s = _spec_for("deepseek-v3-671b", ("layers", "moe", "w_gate"),
                      (61, 256, 7168, 2048))
        assert s == P(None, ("data", "model"), None, None)

    def test_granite_padded_experts_model_parallel(self):
        s = _spec_for("granite-moe-3b-a800m", ("layers", "moe", "w_gate"),
                      (32, 48, 1536, 512))
        assert s == P(None, "model", None, None)

    def test_enc_layers_treated_as_stacked(self):
        s = _spec_for("seamless-m4t-large-v2", ("enc_layers", "attn", "wo", "w"),
                      (24, 1024, 1024))
        assert s == P(None, "model", None)

    def test_vocab_sharding_falls_back_when_indivisible(self):
        s = _spec_for("granite-moe-3b-a800m", ("embed",), (49155, 1536))
        assert s == P(None, None)          # 49155 % 16 != 0
        s = _spec_for("qwen3-8b", ("embed",), (151936, 4096))
        assert s == P("model", None)


class ServeMesh4:
    """Duck-typed 1-D serving mesh, mirroring make_serving_mesh(4)."""
    shape = {"model": 4}
    axis_names = ("model",)


def _serve_spec(arch, keypath, shape, mesh=None):
    from repro.launch.sharding import param_spec

    class Key:
        def __init__(self, k):
            self.key = k
    path = tuple(Key(k) for k in keypath)
    return param_spec(path, jax.ShapeDtypeStruct(shape, jnp.float32),
                      ARCHS[arch], mesh or ServeMesh4())


class TestKVProjectionFallback:
    """gemma3's 4 q / 1 kv heads on a 4-wide serving mesh: q and out stay
    head-parallel while the small K/V projections REPLICATE (the middle
    fallback) instead of row-parallelizing, which would cost a partial-sum
    all-reduce per layer to rebuild tensors 1/4 the q projection's size."""

    def test_gemma3_q_heads_stay_column_parallel(self):
        s = _serve_spec("gemma3-1b", ("layers", "attn", "wq", "w"),
                        (26, 1152, 1024))
        assert s == P(None, None, "model")     # 4 heads % 4 == 0

    def test_gemma3_kv_replicates_not_row_parallel(self):
        for name in ("wk", "wv"):
            s = _serve_spec("gemma3-1b", ("layers", "attn", name, "w"),
                            (26, 1152, 256))
            assert s == P(None, None, None), name   # 1 kv head: replicate
            s = _serve_spec("gemma3-1b", ("layers", "attn", name, "b"),
                            (26, 256))
            assert s == P(None, None), name

    def test_gemma3_out_proj_row_parallel_over_heads(self):
        s = _serve_spec("gemma3-1b", ("layers", "attn", "wo", "w"),
                        (26, 1024, 1152))
        assert s == P(None, "model", None)

    def test_wide_mesh_still_takes_row_parallel_branch(self):
        """On the 16-wide training mesh neither 1 kv nor 4 q heads divide,
        so the pre-existing row-parallel fallback still fires — the new
        middle case must not change training layouts."""
        s = _serve_spec("gemma3-1b", ("layers", "attn", "wk", "w"),
                        (26, 1152, 256), mesh=FakeMesh())
        assert s == P(None, "model", None)     # 1152 % 16 == 0

    def test_divisible_kv_unaffected(self):
        """qwen3 8 kv heads divide 4: K/V keep head-column sharding."""
        s = _serve_spec("qwen3-8b", ("layers", "attn", "wk", "w"),
                        (36, 4096, 1024))
        assert s == P(None, None, "model")


class TestPagedCacheSpec:
    """Pool-plane layouts for Engine(mesh=...) — paged_cache_spec."""

    def _spec(self, keypath, shape):
        from repro.launch.sharding import paged_cache_spec

        class Key:
            def __init__(self, k):
                self.key = k
        path = tuple(Key(k) for k in keypath)
        return paged_cache_spec(path, jax.ShapeDtypeStruct(shape, jnp.uint8),
                                ARCHS["qwen1.5-0.5b"], ServeMesh4())

    def test_gqa_planes_shard_kv_heads(self):
        # (L, NB, BS, Hkv, Hd): 4 kv heads over 4 shards
        assert self._spec(("attn", "k_hi"), (2, 64, 16, 4, 64)) \
            == P(None, None, None, "model", None)

    def test_indivisible_kv_heads_replicate(self):
        # gemma3-style 1 kv head: replicated pool, matching the
        # projection fallback above
        assert self._spec(("shared", "v_lo"), (26, 64, 16, 1, 256)) \
            == P(None, None, None, None, None)

    def test_mla_latents_replicate(self):
        # (L, NB, BS, r): no head axis, block axis unshardable (dynamic
        # scatter indices) -> fully replicated
        assert self._spec(("attn", "c_kv"), (4, 64, 16, 512)) \
            == P(None, None, None, None)

    def test_ssm_state_shards_heads_conv_shards_channels(self):
        assert self._spec(("ssm",), (8, 4, 16, 64, 128)) \
            == P(None, None, "model", None, None)
        assert self._spec(("conv_x",), (8, 4, 3, 1024)) \
            == P(None, None, None, "model")
        assert self._spec(("conv_bc",), (8, 4, 3, 256)) \
            == P(None, None, None, None)


class TestShapePolicy:
    def test_long_500k_skips_full_attention(self):
        ok, why = steps.shape_supported(ARCHS["qwen3-8b"],
                                        INPUT_SHAPES["long_500k"])
        assert not ok and "quadratic" in why

    @pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b",
                                      "gemma3-1b"])
    def test_long_500k_runs_sub_quadratic(self, arch):
        ok, _ = steps.shape_supported(ARCHS[arch], INPUT_SHAPES["long_500k"])
        assert ok

    def test_all_other_shapes_supported_everywhere(self):
        for a in ARCHS.values():
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert steps.shape_supported(a, INPUT_SHAPES[s])[0]


class TestRooflineParsers:
    def test_jaxpr_flops_dense(self):
        f = fcount.count_step_flops(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 4), jnp.float32))
        assert f == pytest.approx(2 * 8 * 16 * 4, rel=0.01)

    def test_jaxpr_flops_scan_multiplies(self):
        def fn(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y
        f = fcount.count_step_flops(
            fn, jax.ShapeDtypeStruct((4, 4), jnp.float32),
            jax.ShapeDtypeStruct((4, 4), jnp.float32))
        assert f == pytest.approx(7 * 2 * 4 * 4 * 4, rel=0.05)

    def test_collective_parser_trip_counts(self):
        hlo = textwrap.dedent("""\
        HloModule m
        %body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
          %ar = f32[64]{0} all-reduce(f32[64]{0} %x), replica_groups={}
          ROOT %t = (s32[], f32[64]) tuple(%i, %ar)
        }
        %cond.2 (p: (s32[], f32[64])) -> pred[] {
          ROOT %c = pred[] compare(s32[] %i, s32[] %n), direction=LT
        }
        ENTRY %main (a: f32[64]) -> f32[64] {
          %ag = f32[128]{0} all-gather(f32[64]{0} %a), dimensions={0}
          %w = (s32[], f32[64]) while(%init), condition=%cond.2, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
          ROOT %r = f32[64] get-tuple-element(%w), index=1
        }
        """)
        res = roof.collective_bytes(hlo)
        # all-gather: 128*4 once; all-reduce: 64*4 x 5 trips
        assert res["bytes_by_type"]["all-gather"] == 128 * 4
        assert res["bytes_by_type"]["all-reduce"] == 64 * 4 * 5
        assert res["counts_by_type"] == {"all-gather": 1, "all-reduce": 1}

    def test_roofline_terms_dominance(self):
        t = roof.roofline_terms(1e12, 1e9, 1e6)
        assert t["dominant"] == "compute_s"
        t = roof.roofline_terms(1e9, 1e12, 1e6)
        assert t["dominant"] == "memory_s"


SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS
from repro.launch import sharding as sh, steps
from repro.models import model as M
from repro.models.layers import Runtime
from repro.models.convert import to_serving
from repro.core.compat import make_compat_mesh

cfg = ARCHS["qwen1.5-0.5b"].reduced()
mesh = make_compat_mesh((2, 4), ("data", "model"), devices=jax.devices())
params = M.init_params(jax.random.PRNGKey(0), cfg)
sp = to_serving(params)
p_shard = sh.tree_shardings(jax.eval_shape(lambda: sp), mesh, sh.param_spec, cfg)
caches = M.init_cache(cfg, 8, 32)
c_shard = sh.tree_shardings(jax.eval_shape(lambda: caches), mesh,
                            sh.cache_spec, cfg)
rt = Runtime(mode="fp16", backend="ref", dtype=jnp.float32)
fn = jax.jit(lambda p, c, t, l: M.decode_step(rt, p, cfg, t, c, l),
             in_shardings=(p_shard, c_shard, None, None),
             out_shardings=(None, c_shard))
tok = jnp.ones((8, 1), jnp.int32)
lens = jnp.full((8,), 4, jnp.int32)
with mesh:
    logits, caches2 = fn(sp, caches, tok, lens)
# compare against single-device execution
logits_ref, _ = M.decode_step(rt, sp, cfg, tok, caches, lens)
err = float(jnp.abs(logits - logits_ref).max())
assert err < 1e-3, err
print("SMALL_MESH_OK", err)
"""


class TestSmallMeshExecution:
    def test_sharded_decode_matches_single_device(self, tmp_path):
        """Actually EXECUTE a sharded decode step on 8 host devices and
        compare numerics against the unsharded run."""
        script = tmp_path / "small_mesh.py"
        script.write_text(SMALL_MESH_SCRIPT)
        r = subprocess.run([sys.executable, str(script)],
                           capture_output=True, text=True, timeout=520,
                           cwd=os.getcwd())
        assert "SMALL_MESH_OK" in r.stdout, r.stdout + r.stderr


class TestZeRO1OptSpec:
    def test_moments_gain_data_axis(self):
        from repro.launch.sharding import opt_state_spec
        # qwen3 mlp gate (36, 4096, 12288): param spec (None,None,model);
        # ZeRO-1 moments shard layer dim over data too
        s = _spec_for("qwen3-8b", ("layers", "mlp", "gate", "w"),
                      (36, 4096, 12288))
        assert s == P(None, None, "model")

        class Key:
            def __init__(self, k):
                self.key = k
        path = tuple(Key(k) for k in ("layers", "mlp", "gate", "w"))
        o = opt_state_spec(path, jax.ShapeDtypeStruct((36, 4096, 12288),
                                                      jnp.float32),
                           ARCHS["qwen3-8b"], FakeMesh())
        assert o == P(None, "data", "model")   # 4096 % 16 == 0

    def test_expert_banks_unchanged(self):
        """dsv3 banks already use the data axis (full EP) — no double use."""
        from repro.launch.sharding import opt_state_spec

        class Key:
            def __init__(self, k):
                self.key = k
        path = tuple(Key(k) for k in ("layers", "moe", "w_gate"))
        o = opt_state_spec(path, jax.ShapeDtypeStruct((61, 256, 7168, 2048),
                                                      jnp.float32),
                           ARCHS["deepseek-v3-671b"], FakeMesh())
        assert o == P(None, ("data", "model"), None, None)
